"""Config system: architectures, input shapes, parallelism, quantization.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact full-size config, citation in ``source``) and
``smoke_config()`` (a reduced same-family variant: <=2 layers,
d_model<=512, <=4 experts) used by the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# quantization / deployment scheme
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "mlp"            # "none" | "mlp" (MLP/FFN pairs quantized)
    scheme: str = "tp-aware"     # "naive-actorder" | "exllama" | "tp-aware"
    group_size: int = 128
    act_order: bool = True
    attn_tp_aware: bool = False  # beyond-paper head-block-constrained fold
    # Row-TP shards of the down projection must be quant-group aligned
    # (paper Sec 2.1 deployment assumption): group size is chosen to tile
    # d_ff / tp_groups so an up-to-tp_groups-way model axis always gets
    # whole groups per shard.
    tp_groups: int = 16
    # Runtime half of the deployment plan, consumed through
    # ``ExecutionPolicy.from_config`` (core/policy.py): the dequant-GEMM
    # kernel ("auto" picks pallas on TPU for ordered layouts, else jnp),
    # the GEMM compute dtype, and the row-TP epilogue collective — a
    # ``CollectiveSpec`` shorthand dispatched by ``comm/dispatch.py``
    # (e.g. "psum", "psum_scatter", "cast:bfloat16", "quant-int8",
    # "none"), or a per-layer ``CollectivePlan`` shorthand
    # ("per-layer:<glob>=<spec>,...,*=<default>", DESIGN.md §7).
    backend: str = "auto"        # "auto" | kernels.dispatch registry key
    compute_dtype: str = "float32"   # "float32" | "bfloat16" | "float16"
    collective: str = "psum"     # comm spec/plan shorthand
    # Decode KV-cache layout (``repro.cache.PageSpec`` via
    # ``ExecutionPolicy.kv``): None -> dense per-slot rows; a page size
    # turns on the paged pool, kv_bits (8|4) additionally quantizes the
    # page payload blockwise.  Runtime-only: excluded from artifact
    # ``validate`` (the weight plan is independent of cache layout).
    kv_page_size: Optional[int] = None
    kv_bits: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    source: str                  # citation (hf model card / arXiv)

    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_type: str = "rms"       # "rms" | "layernorm"
    use_rope: bool = True
    norm_eps: float = 1e-5
    attention_window: Optional[int] = None   # sliding-window decode variant
    causal: bool = True

    # MLP details
    activation: str = "silu"
    mlp_gated: bool = True

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # hybrid (recurrentgemma): pattern of 2 recurrent : 1 local-attn
    lru_width: Optional[int] = None
    conv_width: int = 4
    local_window: int = 2048

    # ssm (rwkv6)
    rwkv_head_dim: int = 64

    # audio (whisper): encoder stack + stub frame embeddings
    encoder_layers: int = 0
    encoder_seq: int = 1500        # mel frames after conv (stub input)
    max_target_positions: int = 0

    # vlm (llama-3.2-vision): cross-attn every Nth layer, stub patch embeds
    cross_attn_every: int = 0
    vision_tokens: int = 1601      # ViT patch embeds incl CLS (stub input)

    quant: QuantConfig = QuantConfig()
    dtype: str = "bfloat16"

    # Deployment head padding: when set to the model-axis size, the
    # (kv, group) head grid is zero-padded so the padded head count shards
    # the axis exactly (GSPMD otherwise pads *implicitly*, emitting
    # pathological collective-permute chains -- measured; DESIGN.md Sec 4).
    # Padded heads are zero-initialized; wo's padded rows are zero, so the
    # function computed is exactly the logical architecture's.
    attn_tp_pad: Optional[int] = None

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def padded_vocab(self) -> int:
        """Deployment vocab padding: round up to the TP degree so the
        embedding/lm_head shard the model axis exactly (padded logit
        columns are masked to -1e30 in lm_head — exact softmax).  Active
        only when ``attn_tp_pad`` (the deployment TP degree) is set."""
        if not self.attn_tp_pad or self.vocab_size % self.attn_tp_pad == 0:
            return self.vocab_size
        tp = self.attn_tp_pad
        return (self.vocab_size + tp - 1) // tp * tp

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_quant(self, **kw) -> "ModelConfig":
        return dataclasses.replace(
            self, quant=dataclasses.replace(self.quant, **kw))

    # ---- roofline helpers -------------------------------------------------
    def param_count(self) -> int:
        """Approximate total parameter count N (for MODEL_FLOPS = 6ND)."""
        d, l = self.d_model, self.num_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family == "ssm":
            attn = d * d * 4  # r,k,v,o time-mix projections
        mlp = d * self.d_ff * (3 if self.mlp_gated else 2)
        moe = 0
        if self.num_experts:
            moe = self.num_experts * d * self.moe_dff * (
                3 if self.mlp_gated else 2) + d * self.num_experts
            if not self.dense_residual:
                mlp = 0
        emb = self.vocab_size * d * 2
        enc = 0
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + mlp)
        return l * (attn + mlp + moe) + emb + enc

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        d, l = self.d_model, self.num_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family == "ssm":
            attn = d * d * 4
        mlp = d * self.d_ff * (3 if self.mlp_gated else 2)
        moe = 0
        if self.num_experts:
            moe = self.top_k * d * self.moe_dff * (
                3 if self.mlp_gated else 2) + d * self.num_experts
            if not self.dense_residual:
                mlp = 0
        emb = self.vocab_size * d  # lm head matmul is active
        return l * (attn + mlp + moe) + emb


def smoke_reduce(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    kw = dict(
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=min(cfg.top_k, 2), moe_dff=128)
    if cfg.lru_width:
        kw.update(lru_width=256, local_window=64)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=32, max_target_positions=128)
    if cfg.cross_attn_every:
        kw.update(cross_attn_every=2, vision_tokens=16)
    if cfg.attention_window:
        kw.update(attention_window=64)
    kw.update(overrides)
    new = cfg.with_(**kw)
    # group size must tile the reduced dims
    from repro.core.quantization import choose_group_size
    gs = choose_group_size(min(new.d_ff if not new.num_experts else new.moe_dff,
                               new.d_model, 128), 64)
    return new.with_quant(group_size=gs)
