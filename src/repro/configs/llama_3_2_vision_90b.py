"""llama-3.2-vision-90b [vlm] — [hf:meta-llama/Llama-3.2-11B-Vision] scaled
per assignment: 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256,
cross-attention image layers every 5th layer; ViT/projector is a STUB
(``input_specs`` provides precomputed patch embeddings)."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B scaling per assignment)",
    num_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    activation="silu",
    mlp_gated=True,
    cross_attn_every=5,
    vision_tokens=1601,
    attention_window=4096,   # sliding-window decode variant for long_500k
)


def smoke_config():
    return smoke_reduce(CONFIG)
