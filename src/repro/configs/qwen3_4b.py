"""qwen3-4b [dense] — [hf:Qwen/Qwen3-8B family] per assignment:
36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, qk_norm."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (4B sibling per assignment)",
    num_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    activation="silu",
    mlp_gated=True,
    attention_window=4096,
)


def smoke_config():
    return smoke_reduce(CONFIG)
