"""granite-3-8b [dense] — [hf:ibm-granite/granite-3.0-2b-base family]:
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="granite-3-8b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base (8B sibling per assignment)",
    num_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10_000.0,
    activation="silu",
    mlp_gated=True,
    attention_window=4096,
)


def smoke_config():
    return smoke_reduce(CONFIG)
