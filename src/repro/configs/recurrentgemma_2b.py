"""recurrentgemma-2b [hybrid] — [arXiv:2402.19427] (Griffin): 26L
d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000; RG-LRU recurrent
blocks : local-attention blocks at 2:1 (pattern rec,rec,attn), window 2048.
Sub-quadratic: runs long_500k natively (bounded state/window)."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="gelu",
    mlp_gated=True,          # GeGLU
    lru_width=2560,
    conv_width=4,
    local_window=2048,
)


def smoke_config():
    return smoke_reduce(CONFIG)
