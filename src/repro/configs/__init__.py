"""Config registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``.

Also exports the paper's own MLP problem sizes (Llama-70B / Granite-20B)
used by the benchmark harness.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES, InputShape, ModelConfig, QuantConfig, smoke_reduce)

ARCH_IDS = (
    "llama-3.2-vision-90b",
    "qwen3-moe-235b-a22b",
    "qwen3-4b",
    "mistral-large-123b",
    "whisper-large-v3",
    "starcoder2-3b",
    "recurrentgemma-2b",
    "rwkv6-3b",
    "arctic-480b",
    "granite-3-8b",
)

_MODULES = {
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen3-4b": "qwen3_4b",
    "mistral-large-123b": "mistral_large_123b",
    "whisper-large-v3": "whisper_large_v3",
    "starcoder2-3b": "starcoder2_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-3b": "rwkv6_3b",
    "arctic-480b": "arctic_480b",
    "granite-3-8b": "granite_3_8b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config()


# ---------------------------------------------------------------------------
# the paper's own MLP problem sizes (benchmarks/)
# ---------------------------------------------------------------------------

PAPER_PROBLEMS = {
    # name: (K1, N1, N2) — up_proj (K1,N1) then down_proj (N1,N2)
    "llama-70b": (8192, 28672, 8192),
    "granite-20b": (6144, 24576, 6144),
}
PAPER_BATCH_SIZES = (1, 2, 4, 8, 16)
PAPER_TP_SETTINGS = (1, 2, 4, 8)
