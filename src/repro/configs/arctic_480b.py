"""arctic-480b [moe] — [hf:Snowflake/snowflake-arctic-base]: 35L
d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
**plus a dense residual MLP** in parallel (dense-MoE hybrid)."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    activation="silu",
    mlp_gated=True,
    num_experts=128,
    top_k=2,
    moe_dff=4864,
    dense_residual=True,
    attention_window=4096,
)


def smoke_config():
    return smoke_reduce(CONFIG)
