"""qwen3-moe-235b-a22b [moe] — [hf:Qwen/Qwen3-30B-A3B] scaled per assignment:
94L d_model=4096 64H (GQA kv=4) d_ff=1536/expert vocab=151936,
MoE 128 experts top-8, qk_norm."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B (235B-A22B scaling per assignment)",
    num_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,           # per-expert ffn width
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    activation="silu",
    mlp_gated=True,
    num_experts=128,
    top_k=8,
    moe_dff=1536,
    attention_window=4096,
)


def smoke_config():
    return smoke_reduce(CONFIG)
