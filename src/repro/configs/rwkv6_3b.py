"""rwkv6-3b [ssm] — [arXiv:2404.05892] (RWKV-6 "Finch"): 32L d_model=2560
(attention-free, data-dependent decay time-mix) d_ff=8960 vocab=65536.
Sub-quadratic: O(1) state, runs long_500k natively.

Paper-technique note (DESIGN.md §5): the TP-aware fold applies to the
channel-mix K->V pair; the time-mix recurrence is elementwise/recurrent and
out of scope for the technique."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892 (RWKV-6 Finch)",
    num_layers=32,
    d_model=2560,
    n_heads=40,              # time-mix heads = d_model / rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    activation="relu2",      # channel-mix uses squared ReLU
    mlp_gated=False,
    rwkv_head_dim=64,
)


def smoke_config():
    return smoke_reduce(CONFIG, n_heads=4, n_kv_heads=4, head_dim=64)
