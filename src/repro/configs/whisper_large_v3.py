"""whisper-large-v3 [audio] — [arXiv:2212.04356]: enc-dec, 32L encoder +
32L decoder, d_model=1280 20H d_ff=5120 vocab=51866. Conv/mel frontend is a
STUB — ``input_specs`` provides precomputed frame embeddings (B, 1500, d).

Shape notes (see DESIGN.md): decode shapes lower the decoder serve_step;
``long_500k`` is skipped (decoder max positions 448 — a 500k decoder context
is architecturally meaningless for this model)."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356 (Whisper), large-v3 card",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    max_target_positions=448,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    norm_type="layernorm",
    use_rope=False,
    mlp_gated=False,
    causal=True,
)


def smoke_config():
    return smoke_reduce(CONFIG)
