"""starcoder2-3b [dense] — [arXiv:2402.19173]: 30L d_model=3072 24H
(GQA kv=2) d_ff=12288 vocab=49152, GQA + RoPE, ungated GELU MLP."""

from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    arch_id="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173 (StarCoder2)",
    num_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=999_999.0,
    activation="gelu",
    norm_type="layernorm",
    mlp_gated=False,
    attention_window=4096,
)


def smoke_config():
    return smoke_reduce(CONFIG)
