"""Batched request scheduler for the serving runtime.

Continuous-batching-lite: requests arrive with arbitrary prompt lengths;
the scheduler packs up to ``max_batch`` of them into one fixed-shape
(B, S) program, right-padding prompts, tracking per-slot progress, and
retiring finished slots so new requests can be admitted between decode
steps.  One compiled executable serves all traffic (shapes never change).

Two drain modes:

* **continuous** (token-granularity, the default wherever the family
  supports per-slot position vectors): one persistent decode program
  steps all ``max_batch`` slots together, each slot running its own
  clock.  A slot that finishes is refilled from the queue at the next
  step boundary — prompt replay and generation are the same decode loop,
  so admission never stalls the other slots.  Numerics per request are
  bit-identical to running it alone: for attention families the causal
  mask hides every other slot's cache rows; for recurrent families
  (rglru/rwkv6) the re-admitted slot's state lane is zeroed
  (``Engine.reset_slot``) — exactly the fresh-cache initial condition.
  This mode is incremental: ``step()`` runs exactly one admission +
  decode step and reports what happened as ``StepEvent``s, which is what
  the serving front end (``repro.serving``) builds its streaming loop
  on; ``run()`` just steps until the queue drains.
* **batch-drain** (legacy fallback, audio/vlm): popleft up to
  ``max_batch`` requests, run them to completion via ``Engine.generate``
  (those families need the batch-global cross-attention prefill).
  Per-request sampling overrides are a continuous-mode feature; this
  path samples with the scheduler-global config.

Cache lifecycle: the decode cache (dense rows or the paged pool) is
built lazily on the first step and — new in the paged-cache PR — freed
again by ``release_cache()`` once the engine idles, so a long-lived
serving loop doesn't pin peak-batch cache memory between traffic bursts.

Paged mode (``engine.uses_page_table``, DESIGN.md §9): a
``PagedCacheManager`` owns per-slot page tables over a shared page pool.
Admission reserves each request's worst-case page count (so mid-decode
growth never deadlocks), credits prefix-shared pages (identical leading
prompt pages skip replay entirely), and ``step()`` threads the table
into the jitted decode.  Exhaustion surfaces as ``can_admit() == False``
— the serving loop then leaves requests queued and its admission queue
backs up into 429s, never a mid-decode failure.

**One scheduler serves one family.**  Continuous and batch-drain
requests cannot interleave inside one queue: a batch-drain wave holds
every lane until its slowest request finishes, so a mixed queue would
silently serialize the continuous traffic behind it.  ``submit``
therefore rejects any request whose declared ``family`` differs from
the engine's — run one ``Scheduler`` (and one engine) per family and
split traffic upstream.

Per-request sampling: ``Request`` carries optional ``temperature`` /
``top_p`` / ``seed`` overriding the scheduler-global ``SamplingConfig``
(``top_k`` stays global).  Each slot owns an independent PRNG chain
seeded from the request (``seed`` if given, else the scheduler seed
folded with the rid), advanced only on emission steps — so a request's
tokens are bit-identical to a solo ``Engine.generate(PRNGKey(seed),
...)`` run with the same params, no matter which other requests share
the batch.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import sampling
from repro.runtime.serve import Engine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (L,) int32
    max_new_tokens: int = 16
    # per-request sampling overrides (None -> the scheduler's global
    # SamplingConfig value); ``seed`` pins this request's sample stream
    # so its output is reproducible independent of batch composition
    temperature: Optional[float] = None
    top_p: Optional[float] = None
    seed: Optional[int] = None
    # declared model family; None means "the engine's own".  Anything
    # else is rejected at submit (one scheduler per family — see module
    # docstring).
    family: Optional[str] = None
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False


@dataclasses.dataclass(frozen=True)
class StepEvent:
    """What one decode step did to one request (continuous mode)."""

    rid: int
    token: Optional[int]           # None for a pure retire (cancel)
    final: bool                    # request left the engine this step
    cancelled: bool = False


@dataclasses.dataclass
class _Slot:
    """One live lane of the fixed-shape decode program."""

    req: Request
    key: jax.Array                 # this request's private sample stream
    fed: int = 0                   # tokens fed so far == this slot's pos
    last: int = 0                  # last sampled token (next input when
                                   # the prompt is exhausted)


class Scheduler:
    def __init__(self, engine: Engine, *, max_batch: int = 8,
                 prompt_budget: int = 128,
                 scfg: sampling.SamplingConfig = sampling.SamplingConfig(),
                 seed: int = 0, n_pages: Optional[int] = None):
        self.engine = engine
        self.max_batch = max_batch
        self.prompt_budget = prompt_budget
        self.scfg = scfg
        self.seed = seed
        self.queue: deque[Request] = deque()
        self.finished: dict[int, Request] = {}
        self.rng = jax.random.PRNGKey(seed)   # batch-drain global chain
        #: (step, rid) log of admissions — step > 0 entries are requests
        #: admitted into retired slots *between* decode steps.
        self.admissions: list[tuple[int, int]] = []
        # continuous-mode engine state, built lazily on the first step
        # and releasable between traffic bursts (release_cache)
        self._cache = None
        self._slots: list[Optional[_Slot]] = []
        self._dirty: list[bool] = []   # slot lanes a retired request used
        self._step_no = 0
        self._cache_builds = 0
        self.manager = None
        if engine.uses_page_table:
            from repro.cache import PagedCacheManager

            self.manager = PagedCacheManager(
                engine.policy.kv, max_batch=max_batch,
                max_seq=engine.max_seq, n_pages=n_pages)
        self._recurrent = engine.model.cfg.family in ("hybrid", "ssm")

    def submit(self, req: Request):
        family = self.engine.model.cfg.family
        if req.family is not None and req.family != family:
            raise ValueError(
                f"request {req.rid} is for family '{req.family}' but this "
                f"scheduler's engine serves '{family}': continuous and "
                "batch-drain families cannot share a queue (a batch-drain "
                "wave would hold every lane until its slowest request "
                "finishes, silently serializing the continuous traffic "
                "behind it) — run one Scheduler per family")
        if req.prompt.size > self.prompt_budget:
            raise ValueError(
                f"prompt {req.prompt.size} > budget {self.prompt_budget}")
        if req.prompt.size + req.max_new_tokens > self.engine.max_seq:
            raise ValueError(
                f"prompt {req.prompt.size} + max_new {req.max_new_tokens} "
                f"> engine max_seq {self.engine.max_seq}")
        if self.manager is not None:
            worst = self.manager.pages_needed(req.prompt.size,
                                              req.max_new_tokens)
            if worst > self.manager.n_pages:
                raise ValueError(
                    f"request {req.rid} needs {worst} pages worst-case but "
                    f"the pool only has {self.manager.n_pages} — it can "
                    "never be admitted")
        self.queue.append(req)

    def can_admit(self, req: Request) -> bool:
        """Would ``step()`` admit this request right now (given a free
        slot)?  Always true for dense caches; in paged mode the request's
        worst-case page reservation must fit the pool next to everything
        live or already queued."""
        if self.manager is None:
            return True
        pending = sum(self.manager.pages_needed(r.prompt.size,
                                                r.max_new_tokens)
                      for r in self.queue)
        return self.manager.can_admit(req.prompt.size, req.max_new_tokens,
                                      pending_pages=pending)

    def cancel(self, rid: int) -> bool:
        """Retire a request: a queued one is dropped immediately, a live
        one at the next step boundary (its slot then frees for
        admission).  Returns False for unknown/already-finished rids."""
        for req in self.queue:
            if req.rid == rid and not req.cancelled:
                req.cancelled = True
                return True
        for slot in self._slots:
            if (slot is not None and slot.req.rid == rid
                    and not slot.req.cancelled):
                slot.req.cancelled = True
                return True
        return False

    @property
    def live_slots(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.live_slots > 0

    def run(self) -> dict[int, Request]:
        """Drain the queue; returns {rid: finished request}."""
        if self.engine.supports_continuous:
            while self.has_work:
                self.step()
            return self.finished
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_batch(batch)
        return self.finished

    # ------------------------------------------------------------------
    # continuous mode: admit into retired slots between decode steps
    # ------------------------------------------------------------------

    def _request_key(self, req: Request) -> jax.Array:
        if req.seed is not None:
            return jax.random.PRNGKey(req.seed)
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), req.rid)

    def step(self) -> list[StepEvent]:
        """One admission + decode step over the fixed-shape program.

        Returns a ``StepEvent`` per request that emitted a token or was
        retired this step.  Safe to call with an empty engine (returns
        ``[]`` without touching the device).
        """
        if not self.engine.supports_continuous:
            raise RuntimeError(
                f"family '{self.engine.model.cfg.family}' does not support "
                "token-granularity stepping (batch-drain only) — use run()")
        b = self.max_batch
        if self._cache is None:
            if self.manager is not None:
                # pool_pages = n_pages + 1: the extra scratch page is
                # where idle lanes' dummy scatters land (manager docs)
                self._cache = self.engine.init_paged_cache(
                    b, self.manager.pool_pages)
                from repro.cache import paged as paged_pool

                pool = self._cache if "k" in self._cache \
                    else self._cache["self"]
                (self.manager.page_bytes,
                 self.manager.page_bytes_fp) = paged_pool.pool_page_bytes(
                     pool, self.manager.pool_pages)
            else:
                self._cache = self.engine.init_cache(b)
            self._slots = [None] * b
            self._dirty = [False] * b
            self._cache_builds += 1
        slots = self._slots
        events: list[StepEvent] = []

        # cancellation: purge queued + retire live cancelled requests at
        # the step boundary, freeing their slots for admission below
        if any(r.cancelled for r in self.queue):
            kept: deque[Request] = deque()
            for req in self.queue:
                if req.cancelled:
                    req.done = True
                    self.finished[req.rid] = req
                    events.append(StepEvent(req.rid, None, True,
                                            cancelled=True))
                else:
                    kept.append(req)
            self.queue = kept
        for i in range(b):
            if slots[i] is not None and slots[i].req.cancelled:
                req = slots[i].req
                req.done = True
                self.finished[req.rid] = req
                events.append(StepEvent(req.rid, None, True,
                                        cancelled=True))
                self._retire_slot(i)

        # admission: every retired (or never-used) slot takes the next
        # queued request NOW — between decode steps, not after a wave.
        # Paged mode additionally requires the head-of-queue's worst-case
        # page reservation to fit; the queue stays FIFO (no skipping), so
        # a too-big head waits rather than being starved by later
        # requests.
        for i in range(b):
            if slots[i] is None and self.queue:
                req = self.queue[0]
                fed0 = 0
                if self.manager is not None:
                    if not self.manager.can_admit(req.prompt.size,
                                                  req.max_new_tokens):
                        break
                    fed0 = self.manager.admit(i, req.prompt,
                                              req.max_new_tokens)
                elif self._recurrent and self._dirty[i]:
                    # recurrent state has no position mask to hide the
                    # previous occupant — zero the lane (== fresh cache)
                    self._cache = self.engine.reset_slot(self._cache, i)
                    self._dirty[i] = False
                self.queue.popleft()
                slots[i] = _Slot(req=req, key=self._request_key(req),
                                 fed=fed0)
                self.admissions.append((self._step_no, req.rid))

        if not any(slots):
            return events

        tokens = np.zeros((b,), np.int32)
        pos = np.zeros((b,), np.int32)
        temperature = np.zeros((b,), np.float32)
        top_p = np.ones((b,), np.float32)
        top_k = np.zeros((b,), np.int32)
        keys = []
        for i, s in enumerate(slots):
            if s is None:
                keys.append(jax.random.PRNGKey(0))
                continue
            plen = s.req.prompt.size
            tokens[i] = (s.req.prompt[s.fed] if s.fed < plen else s.last)
            pos[i] = s.fed
            temperature[i] = (self.scfg.temperature
                              if s.req.temperature is None
                              else s.req.temperature)
            p = self.scfg.top_p if s.req.top_p is None else s.req.top_p
            top_p[i] = 1.0 if p is None else p
            top_k[i] = 0 if self.scfg.top_k is None else self.scfg.top_k
            # the chain mirrors Engine.generate exactly: the first
            # emission samples with the request key itself, every later
            # one splits first — non-emitting (prompt replay) steps pass
            # the current key but never advance it
            if s.fed + 1 >= plen and s.req.output:
                s.key, sub = jax.random.split(s.key)
                keys.append(sub)
            else:
                keys.append(s.key)

        if self.manager is not None:
            for i, s in enumerate(slots):
                if s is not None:
                    self.manager.ensure(i, s.fed)   # page for this scatter
            logits, self._cache = self.engine._decode(
                self.engine.params, self._cache, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(self.manager.table()))
        else:
            logits, self._cache = self.engine._decode(
                self.engine.params, self._cache, jnp.asarray(tokens),
                jnp.asarray(pos))
        sampled = np.asarray(sampling.sample_slots(
            jnp.stack(keys), logits, jnp.asarray(temperature),
            jnp.asarray(top_p), jnp.asarray(top_k)))

        for i, s in enumerate(slots):
            if s is None:
                continue
            s.fed += 1
            if self.manager is not None:
                # owned prompt pages now fully written become shareable
                self.manager.advance(i, s.fed)
            if s.fed >= s.req.prompt.size:
                # this step consumed the prompt's last token (or a
                # generated one): its logits yield the next token
                s.last = int(sampled[i])
                s.req.output.append(s.last)
                final = len(s.req.output) >= s.req.max_new_tokens
                events.append(StepEvent(s.req.rid, s.last, final))
                if final:
                    s.req.done = True
                    self.finished[s.req.rid] = s.req
                    self._retire_slot(i)  # retired: refill next step
        self._step_no += 1
        return events

    def _retire_slot(self, i: int):
        """Free slot ``i``'s lane: paged mode returns its pages (shared
        complete prefix pages park in the allocator's LRU), recurrent
        mode marks the lane dirty so the next occupant resets it."""
        self._slots[i] = None
        self._dirty[i] = True
        if self.manager is not None:
            self.manager.release(i)

    def release_cache(self) -> bool:
        """Drop the decode cache while the engine is idle, so a
        long-lived serving loop doesn't pin peak-batch cache memory
        between traffic bursts.  The paged manager's prefix LRU goes
        with it (its pages index into the freed pool).  No-op (False)
        while any request is live or queued; the next ``step()``
        rebuilds the cache lazily."""
        if self.live_slots or self.queue or self._cache is None:
            return False
        if self.manager is not None:
            self.manager.reset()
        self._cache = None
        self._slots = []
        self._dirty = []
        return True

    def cache_stats(self) -> dict:
        """Cache telemetry for the stats endpoint (DESIGN.md §9)."""
        out: dict = {
            "allocated": self._cache is not None,
            "builds": self._cache_builds,
        }
        if self.manager is None:
            out["spec"] = "dense"
            if self._cache is not None:
                out["bytes"] = {"pool": int(sum(
                    leaf.nbytes for leaf in jax.tree_util.tree_leaves(
                        self._cache)))}
            return out
        out.update(self.manager.stats())
        out["per_request_pages"] = {
            s.req.rid: self.manager.slot_pages(i)
            for i, s in enumerate(self._slots) if s is not None}
        return out

    # ------------------------------------------------------------------
    # legacy batch-drain mode (families needing batch-global prefill)
    # ------------------------------------------------------------------

    def _run_batch(self, batch: list[Request]):
        b = len(batch)
        s = self.prompt_budget
        cfg = self.engine.model.cfg
        tokens = np.zeros((b, s), np.int32)
        plen = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            tokens[i, :r.prompt.size] = r.prompt
            plen[i] = r.prompt.size

        inputs = {"tokens": jnp.asarray(tokens)}
        if cfg.family == "audio":
            inputs["frames"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)

        max_new = max(r.max_new_tokens for r in batch)
        self.rng, sub = jax.random.split(self.rng)
        out = self.engine.generate(sub, inputs, plen,
                                   max_new_tokens=max_new, scfg=self.scfg)
        out = np.asarray(out)
        for i, r in enumerate(batch):
            r.output = out[i, :r.max_new_tokens].tolist()
            r.done = True
            self.finished[r.rid] = r
