"""Batched request scheduler for the serving example.

Continuous-batching-lite: requests arrive with arbitrary prompt lengths;
the scheduler packs up to ``max_batch`` of them into one fixed-shape
(B, S) program, right-padding prompts, tracking per-slot progress, and
retiring finished slots so new requests can be admitted between decode
steps.  One compiled executable serves all traffic (shapes never change).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import sampling
from repro.runtime.serve import Engine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (L,) int32
    max_new_tokens: int = 16
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class Scheduler:
    def __init__(self, engine: Engine, *, max_batch: int = 8,
                 prompt_budget: int = 128,
                 scfg: sampling.SamplingConfig = sampling.SamplingConfig(),
                 seed: int = 0):
        self.engine = engine
        self.max_batch = max_batch
        self.prompt_budget = prompt_budget
        self.scfg = scfg
        self.queue: deque[Request] = deque()
        self.finished: dict[int, Request] = {}
        self.rng = jax.random.PRNGKey(seed)

    def submit(self, req: Request):
        if req.prompt.size > self.prompt_budget:
            raise ValueError(
                f"prompt {req.prompt.size} > budget {self.prompt_budget}")
        self.queue.append(req)

    def run(self) -> dict[int, Request]:
        """Drain the queue; returns {rid: finished request}."""
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_batch(batch)
        return self.finished

    # ------------------------------------------------------------------
    def _run_batch(self, batch: list[Request]):
        b = len(batch)
        s = self.prompt_budget
        cfg = self.engine.model.cfg
        tokens = np.zeros((b, s), np.int32)
        plen = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            tokens[i, :r.prompt.size] = r.prompt
            plen[i] = r.prompt.size

        inputs = {"tokens": jnp.asarray(tokens)}
        if cfg.family == "audio":
            inputs["frames"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)

        max_new = max(r.max_new_tokens for r in batch)
        self.rng, sub = jax.random.split(self.rng)
        out = self.engine.generate(sub, inputs, plen,
                                   max_new_tokens=max_new, scfg=self.scfg)
        out = np.asarray(out)
        for i, r in enumerate(batch):
            r.output = out[i, :r.max_new_tokens].tolist()
            r.done = True
            self.finished[r.rid] = r
