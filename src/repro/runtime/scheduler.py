"""Batched request scheduler for the serving example.

Continuous-batching-lite: requests arrive with arbitrary prompt lengths;
the scheduler packs up to ``max_batch`` of them into one fixed-shape
(B, S) program, right-padding prompts, tracking per-slot progress, and
retiring finished slots so new requests can be admitted between decode
steps.  One compiled executable serves all traffic (shapes never change).

Two drain modes:

* **continuous** (token-granularity, the default wherever the family
  supports per-slot position vectors): one persistent decode program
  steps all ``max_batch`` slots together, each slot running its own
  clock.  A slot that finishes is refilled from the queue at the next
  step boundary — prompt replay and generation are the same decode loop,
  so admission never stalls the other slots.  Numerics per request are
  bit-identical to running it alone (the causal mask hides every other
  slot's cache rows).
* **batch-drain** (legacy fallback, audio/vlm): popleft up to
  ``max_batch`` requests, run them to completion via ``Engine.generate``
  (those families need the batch-global cross-attention prefill).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import sampling
from repro.runtime.serve import Engine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (L,) int32
    max_new_tokens: int = 16
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    """One live lane of the fixed-shape decode program."""

    req: Request
    fed: int = 0                   # tokens fed so far == this slot's pos
    last: int = 0                  # last sampled token (next input when
                                   # the prompt is exhausted)


class Scheduler:
    def __init__(self, engine: Engine, *, max_batch: int = 8,
                 prompt_budget: int = 128,
                 scfg: sampling.SamplingConfig = sampling.SamplingConfig(),
                 seed: int = 0):
        self.engine = engine
        self.max_batch = max_batch
        self.prompt_budget = prompt_budget
        self.scfg = scfg
        self.queue: deque[Request] = deque()
        self.finished: dict[int, Request] = {}
        self.rng = jax.random.PRNGKey(seed)
        #: (step, rid) log of admissions — step > 0 entries are requests
        #: admitted into retired slots *between* decode steps.
        self.admissions: list[tuple[int, int]] = []

    def submit(self, req: Request):
        if req.prompt.size > self.prompt_budget:
            raise ValueError(
                f"prompt {req.prompt.size} > budget {self.prompt_budget}")
        if req.prompt.size + req.max_new_tokens > self.engine.max_seq:
            raise ValueError(
                f"prompt {req.prompt.size} + max_new {req.max_new_tokens} "
                f"> engine max_seq {self.engine.max_seq}")
        self.queue.append(req)

    def run(self) -> dict[int, Request]:
        """Drain the queue; returns {rid: finished request}."""
        if self.engine.supports_continuous:
            return self._run_continuous()
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_batch(batch)
        return self.finished

    # ------------------------------------------------------------------
    # continuous mode: admit into retired slots between decode steps
    # ------------------------------------------------------------------

    def _run_continuous(self) -> dict[int, Request]:
        b = self.max_batch
        cache = self.engine.init_cache(b)
        slots: list[Optional[_Slot]] = [None] * b
        decode = self.engine._decode
        params = self.engine.params
        step = 0

        while self.queue or any(slots):
            # admission: every retired (or never-used) slot takes the next
            # queued request NOW — between decode steps, not after a wave.
            for i in range(b):
                if slots[i] is None and self.queue:
                    slots[i] = _Slot(req=self.queue.popleft())
                    self.admissions.append((step, slots[i].req.rid))

            tokens = np.zeros((b,), np.int32)
            pos = np.zeros((b,), np.int32)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                plen = s.req.prompt.size
                tokens[i] = (s.req.prompt[s.fed] if s.fed < plen else s.last)
                pos[i] = s.fed

            logits, cache = decode(params, cache, jnp.asarray(tokens),
                                   jnp.asarray(pos))
            self.rng, sub = jax.random.split(self.rng)
            sampled = np.asarray(sampling.sample(sub, logits, self.scfg))

            for i, s in enumerate(slots):
                if s is None:
                    continue
                s.fed += 1
                if s.fed >= s.req.prompt.size:
                    # this step consumed the prompt's last token (or a
                    # generated one): its logits yield the next token
                    s.last = int(sampled[i])
                    s.req.output.append(s.last)
                    if len(s.req.output) >= s.req.max_new_tokens:
                        s.req.done = True
                        self.finished[s.req.rid] = s.req
                        slots[i] = None      # retired: refill next step
            step += 1
        return self.finished

    # ------------------------------------------------------------------
    # legacy batch-drain mode (families needing batch-global prefill)
    # ------------------------------------------------------------------

    def _run_batch(self, batch: list[Request]):
        b = len(batch)
        s = self.prompt_budget
        cfg = self.engine.model.cfg
        tokens = np.zeros((b, s), np.int32)
        plen = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            tokens[i, :r.prompt.size] = r.prompt
            plen[i] = r.prompt.size

        inputs = {"tokens": jnp.asarray(tokens)}
        if cfg.family == "audio":
            inputs["frames"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)

        max_new = max(r.max_new_tokens for r in batch)
        self.rng, sub = jax.random.split(self.rng)
        out = self.engine.generate(sub, inputs, plen,
                                   max_new_tokens=max_new, scfg=self.scfg)
        out = np.asarray(out)
        for i, r in enumerate(batch):
            r.output = out[i, :r.max_new_tokens].tolist()
            r.done = True
            self.finished[r.rid] = r
