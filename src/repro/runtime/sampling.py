"""Token sampling: greedy / temperature / top-k (pure jnp, jit-able)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0     # 0 -> greedy
    top_k: Optional[int] = None


def sample(rng, logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """logits: (B, V) -> token ids (B,)."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
