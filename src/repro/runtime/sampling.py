"""Token sampling: greedy / temperature / top-k / top-p (pure jnp).

Two entry points share one masking pipeline so their numerics are
bit-identical:

* ``sample(rng, logits, cfg)`` — program-global params, one PRNG key for
  the whole (B, V) batch (``Engine.generate`` and the batch-drain
  scheduler path).
* ``sample_slots(keys, logits, temperature, top_p, top_k)`` — per-slot
  parameter *vectors* with one PRNG key per row, for the continuous
  decode loop where every live slot may carry its own request's
  ``temperature``/``top_p``/``seed``.  A single row of ``sample_slots``
  equals ``sample`` on the (1, V) slice with the same key: the masking
  math is the same code, and ``jax.random.categorical`` draws identical
  gumbel bits for shapes (1, V) and (V,).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0     # 0 -> greedy
    top_k: Optional[int] = None
    top_p: Optional[float] = None  # nucleus: keep smallest prefix with
                                   # cumulative prob >= top_p (None/1.0
                                   # -> no-op)


def _masked_logits(logits: jax.Array, temperature: jax.Array,
                   top_p: jax.Array, top_k: jax.Array) -> jax.Array:
    """Shared mask pipeline: scale -> top-k -> top-p.  All params are
    per-row vectors (B,); ``top_k == 0`` / ``top_p == 1.0`` disable the
    respective mask; ``temperature <= 0`` rows are scaled by 1 (their
    result is replaced by argmax in the callers)."""
    b, v = logits.shape
    t = jnp.where(temperature > 0, temperature, 1.0)
    scaled = logits / t[:, None]

    # top-k: kth-largest per row via take_along_axis on the sorted copy
    # (k == 0 -> index 0, i.e. the row minimum -> keeps everything).
    srt = jnp.sort(scaled, axis=-1)                       # ascending
    k = jnp.clip(top_k, 0, v)
    kth = jnp.take_along_axis(
        srt, jnp.maximum(v - k, 0)[:, None], axis=-1)     # (B, 1)
    scaled = jnp.where((k > 0)[:, None] & (scaled < kth),
                       -jnp.inf, scaled)

    # top-p over the post-top-k distribution: keep every token whose
    # preceding cumulative mass (descending order) is < top_p; the
    # top-1 token always survives.  top_p == 1.0 keeps every token of
    # nonzero probability, which leaves the categorical unchanged.
    probs = jax.nn.softmax(scaled, axis=-1)
    srt_p = jnp.sort(probs, axis=-1)[:, ::-1]             # descending
    cum = jnp.cumsum(srt_p, axis=-1)
    keep = (cum - srt_p) < top_p[:, None]
    thr = jnp.min(jnp.where(keep, srt_p, jnp.inf), axis=-1)  # (B,)
    return jnp.where(probs < thr[:, None], -jnp.inf, scaled)


def _param_vectors(b: int, cfg: SamplingConfig):
    temperature = jnp.full((b,), cfg.temperature, jnp.float32)
    top_p = jnp.full((b,), 1.0 if cfg.top_p is None else cfg.top_p,
                     jnp.float32)
    top_k = jnp.full((b,), 0 if cfg.top_k is None else cfg.top_k,
                     jnp.int32)
    return temperature, top_p, top_k


def sample(rng, logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """logits: (B, V) -> token ids (B,).  One key, program-global cfg."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temperature, top_p, top_k = _param_vectors(logits.shape[0], cfg)
    masked = _masked_logits(logits, temperature, top_p, top_k)
    return jax.random.categorical(rng, masked, axis=-1).astype(jnp.int32)


def sample_slots(keys: jax.Array, logits: jax.Array,
                 temperature: jax.Array, top_p: jax.Array,
                 top_k: jax.Array) -> jax.Array:
    """Per-slot sampling for the continuous decode loop.

    ``keys``: (B,) stacked PRNG keys (i.e. shape (B, 2) uint32) — one
    independent stream per slot so a request's tokens do not depend on
    which other requests share the batch; ``temperature``/``top_p``:
    (B,) float32; ``top_k``: (B,) int32 (0 disables).  Rows with
    ``temperature <= 0`` are greedy (no randomness consumed).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    masked = _masked_logits(logits, temperature, top_p, top_k)
    drawn = jax.vmap(
        lambda k, row: jax.random.categorical(k, row))(keys, masked)
    return jnp.where(temperature <= 0.0, greedy,
                     drawn.astype(jnp.int32))
