"""Serving engine: prefill + decode steps over any registry model.

``Engine`` owns jitted ``prefill`` and ``decode_step`` closures.  Prefill
runs the full forward and writes the prompt's KV into the cache by
replaying tokens through ``decode_step``'s cache writer in one fused scan
for attention archs; recurrent archs thread their O(1) state natively.

The engine is deliberately single-program: batching across requests is the
scheduler's job (``runtime/scheduler.py``) — requests are padded into the
fixed (B, S) program shapes so one compiled executable serves all traffic.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ExecutionPolicy
from repro.models.common import ParallelContext, REPLICATED
from repro.models.registry import Model, build_model
from repro.runtime import sampling


@dataclasses.dataclass
class Engine:
    model: Model
    params: Any
    ctx: ParallelContext = REPLICATED
    max_seq: int = 2048
    window: Optional[int] = None
    # The deployment plan every quantized GEMM — kernel backend, dtypes,
    # and the row-TP epilogue ``CollectiveSpec`` — executes under.  None
    # derives it from the model config; the resolved policy is injected
    # into ``ctx`` so model code sees one source of truth.
    policy: Optional[ExecutionPolicy] = None
    # The artifact's aux plans (precompiled attention V->O folds) — closed
    # over by the jitted step functions for families that consume them.
    aux: Optional[Any] = None
    # Per-rank load ledger (``dist.loader.RankLoadStats``) when the params
    # came from ``DeploymentArtifact.load_for_mesh`` — surfaced so the
    # launcher/banner can report which rank files this process read.
    load_stats: Optional[Any] = None

    def __post_init__(self):
        cfg = self.model.cfg
        mod = self.model

        if self.policy is None:
            self.policy = (self.ctx.policy if self.ctx.policy is not None
                           else ExecutionPolicy.from_config(cfg))
        if self.ctx.policy is None:
            self.ctx = dataclasses.replace(self.ctx, policy=self.policy)
        elif self.ctx.policy != self.policy:
            raise ValueError(
                "Engine got conflicting deployment plans: "
                f"policy={self.policy} but ctx.policy={self.ctx.policy}; "
                "pass one (the ctx policy is what model code executes)")
        aux = self.aux

        def prefill_logits(params, batch):
            return mod.forward(params, batch, self.ctx, window=self.window,
                               aux=aux)

        def decode(params, cache, tokens, pos, pages=None):
            return mod.decode_step(params, cache, tokens, pos, self.ctx,
                                   window=self.window, pages=pages, aux=aux)

        def reset_slot(cache, slot):
            # zero one slot's lane across every per-slot state leaf
            # (batch is dim 1 everywhere: (L/ns, B, ...)).  Used when a
            # recurrent family's slot is re-admitted mid-stream — unlike
            # KV rows, conv/lru/wkv state has no position mask to hide
            # the previous occupant.
            return jax.tree_util.tree_map(
                lambda leaf: leaf.at[:, slot].set(
                    jnp.zeros_like(leaf[:, slot])), cache)

        self._prefill = jax.jit(prefill_logits)
        self._decode = jax.jit(decode, donate_argnums=1)
        self._reset_slot = jax.jit(reset_slot, donate_argnums=0)
        self._replicate = None   # lazily-built logits all-gather (multiproc)

    # ------------------------------------------------------------------
    def _host(self, logits):
        """Logits -> host values the eager sampling/scheduling code may
        touch.  Single-controller: the array is fully addressable, return
        it as-is (zero cost).  Multi-controller: jitted outputs can be
        sharded over the data axis, and eager ops on non-addressable
        global arrays raise — all-gather to replicated (a jitted identity
        with ``out_shardings=P()``) and pull to numpy; every process then
        steps the same host-side sampling, keeping the controllers in
        lockstep."""
        if jax.process_count() == 1:
            return logits
        if self._replicate is None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            self._replicate = jax.jit(
                lambda a: a,
                out_shardings=NamedSharding(self.ctx.mesh, P()))
        return np.asarray(self._replicate(logits))

    # ------------------------------------------------------------------
    @property
    def supports_continuous(self) -> bool:
        """True when the scheduler may run this model at token granularity
        with per-slot position vectors (continuous batching).

        dense/moe qualify because their ENTIRE decode state is the
        position-masked KV cache: a reused slot's stale rows are hidden by
        the ``j <= pos`` mask, so admission is bit-exact.  ssm/hybrid
        carry per-lane *recurrent* state (rwkv6 wkv/shift, rglru conv/lru)
        with no mask to reset it — the scheduler instead zeroes the
        re-admitted slot's lane (``reset_slot``), which is exactly the
        fresh-cache initial condition, so they run continuously too
        (their fixed-size state is a single accounting page).  audio/vlm
        stay batch-drained: the cross-attention prefill (frames/patches)
        is batch-global."""
        return self.model.cfg.family in ("dense", "moe", "hybrid", "ssm")

    @property
    def uses_page_table(self) -> bool:
        """True when decode steps take a page-table argument: a paged
        policy AND a family whose KV grows with the sequence.  Recurrent
        families under a paged policy keep dense fixed-size state."""
        return self.policy.kv.paged and self.model.supports_paged

    def init_cache(self, batch: int):
        cache = self.model.init_cache(batch, self.max_seq,
                                      window=self.window)
        cfg = self.model.cfg
        if cfg.family in ("audio", "vlm"):
            # cross K/V filled at prefill (precompute_cross)
            pass
        return cache

    def init_paged_cache(self, batch: int, n_pages: int):
        spec = self.policy.kv
        return self.model.init_paged_cache(batch, n_pages, spec.page_size,
                                           bits=spec.bits)

    def reset_slot(self, cache, slot: int):
        """Zero one slot's lane of a dense per-slot cache (recurrent
        state reset on re-admission)."""
        return self._reset_slot(cache, slot)

    def prefill(self, batch_inputs: dict, cache, prompt_len: jax.Array):
        """Run the prompt; returns (last_logits (B, V), cache).

        ``batch_inputs["tokens"]``: (B, S) right-padded prompts;
        ``prompt_len``: (B,) true lengths.  The cache is filled by replaying
        tokens through the decode path (one lax.scan over S) — identical
        numerics to the decode program that follows.
        """
        tokens = batch_inputs["tokens"]
        b, s = tokens.shape
        cfg = self.model.cfg

        if cfg.family == "audio":
            from repro.models import whisper

            enc = whisper.encode(cfg, self.params, batch_inputs["frames"],
                                 self.ctx)
            ks, vs = whisper.precompute_cross(cfg, self.params, enc, self.ctx)
            cache = dict(cache, cross_k=ks.astype(cache["cross_k"].dtype),
                         cross_v=vs.astype(cache["cross_v"].dtype))
        if cfg.family == "vlm":
            from repro.models import vision_llama

            ks, vs = vision_llama.precompute_cross(
                cfg, self.params, batch_inputs["patches"], self.ctx)
            cache = dict(cache, cross_k=ks.astype(cache["cross_k"].dtype),
                         cross_v=vs.astype(cache["cross_v"].dtype))

        decode = self._decode

        def scan_fn(carry, t):
            cache, last = carry
            logits, cache = decode(self.params, cache, tokens[:, t], t)
            keep = (t == prompt_len - 1)[:, None]
            last = jnp.where(keep, self._host(logits), last)
            return (cache, last), None

        # python loop over prompt positions (jit'd step): keeps memory flat
        # and matches decode numerics exactly.
        last = jnp.zeros((b, cfg.vocab_size), jnp.float32)
        carry = (cache, last)
        for t in range(s):
            carry, _ = scan_fn(carry, jnp.int32(t))
        cache, last = carry
        return last, cache

    def generate(self, rng, batch_inputs: dict, prompt_len, *,
                 max_new_tokens: int = 32,
                 scfg: sampling.SamplingConfig = sampling.SamplingConfig()):
        """Batched generation; returns (B, max_new_tokens) token ids."""
        tokens = batch_inputs["tokens"]
        b, s = tokens.shape
        prompt_len = jnp.asarray(prompt_len, jnp.int32)
        cache = self.init_cache(b)
        logits, cache = self.prefill(batch_inputs, cache, prompt_len)

        out = []
        pos = prompt_len.max()
        tok = sampling.sample(rng, logits, scfg)
        out.append(tok)
        for i in range(max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, cache, tok, pos + i)
            tok = sampling.sample(sub, self._host(logits), scfg)
            out.append(tok)
        return jnp.stack(out, axis=1)


def make_engine(cfg, rng=None, *, ctx: ParallelContext = REPLICATED,
                max_seq: int = 2048, window=None,
                policy: Optional[ExecutionPolicy] = None,
                artifact=None, per_rank: Optional[bool] = None) -> Engine:
    """Build a serving engine.

    ``artifact``: a ``DeploymentArtifact`` (or its directory path) from
    ``plan`` / ``launch.serve prepare``.  The engine then serves the
    precompiled plan — no GPTQ, no layout planning at load time — after
    validating the artifact's manifest against ``cfg``, the effective
    policy, and the mesh's model-axis degree (a mismatched plan raises
    ``PlanMismatchError`` instead of silently serving).  Without an
    artifact, ``Model.init`` runs the identical compiler in memory.

    ``per_rank``: load the artifact via ``load_for_mesh`` — each process
    reads only its own ranks' ``rank_NN.npz`` files and assembles
    mesh-sharded global arrays (DESIGN.md §11).  Default (None): on when
    this is a multi-process launch.  Requires a directory path and a mesh.
    """
    model = build_model(cfg)
    aux = None
    load_stats = None
    if artifact is not None:
        from repro.plan import DeploymentArtifact

        if per_rank is None:
            per_rank = jax.process_count() > 1
        if isinstance(artifact, (str, bytes)):
            if per_rank:
                if ctx.mesh is None:
                    raise ValueError(
                        "per-rank artifact loading needs a mesh (pass a "
                        "ParallelContext with ctx.mesh set)")
                artifact = DeploymentArtifact.load_for_mesh(artifact,
                                                            ctx.mesh)
            else:
                artifact = DeploymentArtifact.load(artifact)
        eff_policy = policy
        if eff_policy is None:
            eff_policy = (ctx.policy if ctx.policy is not None
                          else ExecutionPolicy.from_config(cfg))
        tp = ctx.axis_size(ctx.model_axis) if ctx.mesh is not None else 1
        artifact.validate(cfg=cfg, policy=eff_policy, tp=tp)
        params = artifact.params()
        aux = artifact.aux   # precompiled V->O folds (None when absent)
        load_stats = artifact.load_stats
    else:
        params = model.init(rng if rng is not None else jax.random.PRNGKey(0))
    return Engine(model=model, params=params, ctx=ctx, max_seq=max_seq,
                  window=window, policy=policy, aux=aux,
                  load_stats=load_stats)
