"""Offline model-quantization pipeline.

Walks a dense param pytree and replaces every MLP weight dict
(``{"w_up", "w_down"[, "w_gate"]}``) with a deployment-ready
``PlannedPair`` in the requested scheme — handling arbitrarily stacked
leading dims (L for dense layers, (L, E) for MoE experts, (ns, nself) for
the VLM's inner self-attention stacks) by nested vmap.

act_order emulation follows the paper exactly (Eq. 2: "we use a random
permutation function φ to emulate an arbitrary reordering"); callers doing
real calibration pass per-pair Hessians to ``reorder.plan_pair`` directly
(see ``tests/test_quantization.py::test_gptq_hessian_reduces_error``).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import reorder
from repro.core.quantization import choose_group_size


def _is_mlp_dict(node: Any) -> bool:
    return (isinstance(node, dict) and "w_up" in node and "w_down" in node)


def _plan_stacked(node: dict, *, scheme: str, group_size: int,
                  act_order: bool, rng) -> Any:
    """plan_pair vmapped over the stacked leading dims of the weights."""
    w_up, w_down = node["w_up"], node["w_down"]
    w_gate = node.get("w_gate")
    lead = w_up.ndim - 2

    gs_up = choose_group_size(w_up.shape[-2], group_size)
    gs_down = choose_group_size(w_down.shape[-2], group_size)

    def plan_one(*args):
        if w_gate is None:
            wu, wd, r = args
            wg = None
        else:
            wu, wd, wg, r = args
        return reorder.plan_pair(
            wu, wd, w_gate=wg, scheme=scheme,
            group_size_up=gs_up, group_size_down=gs_down,
            act_order=act_order, rng=r)

    if lead == 0:
        args = (w_up, w_down, rng) if w_gate is None else (
            w_up, w_down, w_gate, rng)
        return plan_one(*args)

    nstack = 1
    for d in w_up.shape[:lead]:
        nstack *= d
    rngs = jax.random.split(rng, nstack).reshape(*w_up.shape[:lead], 2)

    f = plan_one
    for _ in range(lead):
        f = jax.vmap(f)
    args = (w_up, w_down, rngs) if w_gate is None else (
        w_up, w_down, w_gate, rngs)
    return f(*args)


def quantize_model(cfg: ModelConfig, params: Any, *,
                   scheme: Optional[str] = None,
                   group_size: Optional[int] = None,
                   act_order: Optional[bool] = None,
                   rng=None) -> Any:
    """Dense params -> deployment params with quantized MLP pairs.

    Defaults come from ``cfg.quant``.  Non-MLP weights (attention,
    embeddings, norms, recurrences) stay dense — matching the paper's scope
    (the technique applies to the MLP column-TP/row-TP pair; attention
    folding is the beyond-paper extension in ``core/attention_fold.py``).
    """
    scheme = scheme or cfg.quant.scheme
    group_size = group_size or cfg.quant.group_size
    act_order = cfg.quant.act_order if act_order is None else act_order
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    counter = [0]

    def walk(node):
        if _is_mlp_dict(node):
            counter[0] += 1
            sub = jax.random.fold_in(rng, counter[0])
            return _plan_stacked(node, scheme=scheme, group_size=group_size,
                                 act_order=act_order, rng=sub)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params)
