"""Offline model-quantization entry point for *trained* dense params.

``quantize_model`` is a thin wrapper over the plan compiler's quantize +
layout stages (``plan/compiler.py``) — the ONE pipeline that also backs
``Model.init`` and ``prepare`` — so a trained checkpoint and a random
init take the identical path from dense weights to deployment-ready
``PlannedPair``s (arbitrarily stacked leading dims: L for dense layers,
(L, E) for MoE experts, the VLM's inner self-attention stacks).

act_order emulation follows the paper exactly (Eq. 2: "we use a random
permutation function φ to emulate an arbitrary reordering"); callers doing
real calibration pass per-pair Hessians to ``reorder.plan_pair`` directly
(see ``tests/test_quantization.py::test_gptq_hessian_reduces_error``).
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro.configs.base import ModelConfig
from repro.plan import compiler


def quantize_model(cfg: ModelConfig, params: Any, *,
                   scheme: Optional[str] = None,
                   group_size: Optional[int] = None,
                   act_order: Optional[bool] = None,
                   rng=None) -> Any:
    """Dense params -> deployment params with quantized MLP pairs.

    Defaults come from ``cfg.quant``.  Non-MLP weights (attention,
    embeddings, norms, recurrences) stay dense — matching the paper's scope
    (the technique applies to the MLP column-TP/row-TP pair; attention
    folding is the beyond-paper extension in ``core/attention_fold.py``,
    compiled by the ``stage_fold_attention`` pipeline stage).
    """
    qcfg = cfg
    overrides = {}
    if scheme is not None:
        overrides["scheme"] = scheme
    if group_size is not None:
        overrides["group_size"] = group_size
    if act_order is not None:
        overrides["act_order"] = act_order
    if overrides:
        qcfg = cfg.with_quant(**overrides)
    return compiler.compile_params(
        qcfg, params,
        rng=rng if rng is not None else jax.random.PRNGKey(0))
