"""Distributed runtime subsystem (DESIGN.md §11).

Three pillars over the serve path:

* ``topology``  — ``MeshPlan``: the frozen DP×TP(×EP) device grid,
  carried on ``ExecutionPolicy.mesh`` and recorded in the artifact
  manifest (``"dp2xtp4"`` shorthand).
* ``loader``    — per-rank artifact loading: each process reads only the
  ``rank_NN.npz`` files its addressable devices' model-axis coordinates
  name, and assembles global arrays from per-device addressable shards
  (``jax.make_array_from_single_device_arrays``) — no host ever
  materializes another rank's slices.
* ``overlap``   — the ``:overlap`` epilogue mode for the quantized
  collectives: the two-phase ring is decomposed into explicit
  ``ppermute`` rotations and the epilogue is microbatch-pipelined so the
  ring of one microbatch is in flight while the next microbatch's
  dequant-GEMM computes — bit-identical to the synchronous strategy.
"""

from repro.dist.loader import RankLoadStats, load_per_rank
from repro.dist.topology import MeshPlan, local_model_ranks

__all__ = ["MeshPlan", "RankLoadStats", "load_per_rank",
           "local_model_ranks"]
