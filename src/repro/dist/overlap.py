"""Overlapped quantized TP epilogues: the decomposed, pipelined ring.

The synchronous quantized collectives (``comm/dispatch.py``) close a
row-TP layer with one ``all_to_all`` + one ``all_gather`` issued *after*
the down GEMM — the exposed-collective pattern Xu et al. 2025
(PAPERS.md) show dominates decode latency.  This module re-expresses the
SAME two-phase ring as explicit single-step ``ppermute`` rotations and
pipelines the epilogue over row microbatches, so each microbatch's ring
is in flight while the next microbatch's dequant-GEMM computes:

    gemm(mb0) -> ring_start(mb0) -> gemm(mb1) -> ring_finish(mb0)
                                 -> ring_start(mb1) -> ring_finish(mb1)

Two mechanisms make the overlap real rather than hoped-for:

* ``ring_start`` returns the raw ``ppermute`` results WITHOUT scattering
  them into the collect buffer — assembly happens in ``ring_finish``, so
  the first consumer of every rotation sits on the far side of the next
  microbatch's GEMM in the data-flow graph.
* ``pipelined_epilogue`` threads ``jax.lax.optimization_barrier`` ties:
  the pending ring's results gate on the next GEMM's output (always), so
  no scheduler can close the ring before the GEMM it should hide behind;
  and on backends whose collectives are synchronous instructions (CPU
  XLA never emits ``collective-permute-start``) the next GEMM's *input*
  additionally gates on the rotations, pinning issue order so the
  scheduled module provably exhibits the window.  On async backends that
  second tie is skipped — the ``-start`` may hoist as early as the
  scheduler likes and only the ``-done`` is held past the GEMM.

``launch/roofline.parse_overlap_windows`` verifies either encoding from
the compiled HLO: the window of a collective (or its ``-start``) is the
scheduled span up to its first consumer (the ``-done`` for async pairs),
and overlap means a dequant-GEMM lands inside it.

Bit-identity (asserted in tests at tp ∈ {2, 4, 8}, int8 and int4, plain
and ``:fused``):

* Row-slicing the down GEMM is exact — each output row is an independent
  dot product, and the wire quantization blocks run along the LAST dim,
  so microbatching changes no arithmetic.
* The deferred-assembly collect reproduces ``all_to_all(split_axis=0,
  concat_axis=0, tiled=True)`` element-for-element: slot ``j`` of the
  assembled buffer holds rank ``j``'s chunk, the exact layout the
  synchronous exchange dequant-accumulates (same summation order, same
  f32 adds).
* ``_rotate_gather`` + ``_merge_last`` reproduce ``all_gather(axis=-1,
  tiled=True)``; quantization blocks never straddle chunk boundaries
  (``bs | chunk`` by construction), so the local dequantize sees
  identical blocks.

What this module does NOT do: defer the ring past the next *layer*'s
GEMM.  The transformer's residual + norm consume the closed epilogue
before the next layer's inputs exist, so cross-layer deferral cannot be
bit-identical; the pipelining here overlaps the ring with the same
site's remaining GEMM work instead (DESIGN.md §11 discusses the
trade-off honestly).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.comm.dispatch import (_blockwise_dequantize,
                                 _blockwise_dequantize_int4,
                                 _blockwise_quantize,
                                 _blockwise_quantize_int4, _pack4_last,
                                 _unpack4_last)
from repro.core.quantization import PACK, choose_group_size

__all__ = ["PendingEpilogue", "ring_start", "ring_start_wire",
           "ring_finish", "apply_overlapped", "apply_wire_overlapped",
           "pipelined_epilogue"]


# ---------------------------------------------------------------------------
# decomposed ring primitives
# ---------------------------------------------------------------------------

def _rotate_collect(parts, axis: str, tp: int):
    """Issue phase 1: ``all_to_all(split_axis=0, concat_axis=0,
    tiled=True)`` decomposed into ``tp - 1`` single-step ``ppermute``
    rotations per payload part.

    Each array in ``parts`` is this rank's chunked payload ``(tp, ...)``
    — slot ``d`` the chunk destined for rank ``d``.  At rotation step
    ``s`` every rank sends its chunk for rank ``(r + s) % tp`` and
    receives from rank ``(r - s) % tp``; the own chunk never touches the
    wire.  Returns, per part, ``(own_chunk, received_pieces)`` WITHOUT
    scattering into the collect buffer — ``_assemble_collect`` does that
    in ``ring_finish``, so the rotations' first consumers land after
    whatever the pipeline schedules in between (the overlap window).
    """
    r = jax.lax.axis_index(axis)
    collected = []
    for p in parts:
        own = jnp.take(p, r, axis=0)
        recvs = []
        for s in range(1, tp):
            perm = [(src, (src + s) % tp) for src in range(tp)]
            send = jnp.take(p, (r + s) % tp, axis=0)
            recvs.append(jax.lax.ppermute(send, axis, perm))
        collected.append((own, tuple(recvs)))
    return tuple(collected)


def _assemble_collect(collected, axis: str, tp: int):
    """Scatter the phase-1 pieces by SOURCE rank: slot ``j`` of each
    returned ``(tp, ...)`` buffer holds the chunk rank ``j`` sent here —
    the exact ``all_to_all`` layout the synchronous exchange reduces."""
    r = jax.lax.axis_index(axis)
    outs = []
    for own, recvs in collected:
        buf = jnp.zeros((tp,) + own.shape, own.dtype).at[r].set(own)
        for s, recv in enumerate(recvs, start=1):
            buf = buf.at[(r - s) % tp].set(recv)
        outs.append(buf)
    return tuple(outs)


def _rotate_gather(parts, axis: str, tp: int):
    """``all_gather`` into a new leading source axis, decomposed into
    ``tp - 1`` rotations: slot ``j`` of each returned ``(tp, ...)`` array
    holds rank ``j``'s copy of that array."""
    r = jax.lax.axis_index(axis)
    outs = []
    for p in parts:
        buf = jnp.zeros((tp,) + p.shape, p.dtype).at[r].set(p)
        for s in range(1, tp):
            perm = [(src, (src + s) % tp) for src in range(tp)]
            recv = jax.lax.ppermute(p, axis, perm)
            buf = buf.at[(r - s) % tp].set(recv)
        outs.append(buf)
    return tuple(outs)


def _merge_last(stacked: jax.Array) -> jax.Array:
    """Source-stacked ``(tp, ..., c)`` -> ``(..., tp * c)``: the layout
    ``all_gather(axis=-1, tiled=True)`` produces."""
    out = jnp.moveaxis(stacked, 0, -2)
    return out.reshape(*out.shape[:-2], out.shape[-2] * out.shape[-1])


# ---------------------------------------------------------------------------
# start / finish halves of the epilogue
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PendingEpilogue:
    """An in-flight ring: phase 1 has been issued, phase 2 has not.

    A pytree (so it threads through ``optimization_barrier``): holding
    one of these across other compute IS the overlap — the phase-1
    ``ppermute`` results are first consumed by ``ring_finish``, so
    everything scheduled in between sits inside the collectives' async
    windows.
    """

    parts: tuple          # ((own, (recv_1, ...)), ...) per payload part
    axis: str
    tp: int
    bits: int
    bs: int
    n: int                # logical output dim (pre-padding)
    n_pad: int
    out_dtype: Any

    def tree_flatten(self):
        return ((self.parts,),
                (self.axis, self.tp, self.bits, self.bs, self.n,
                 self.n_pad, self.out_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        axis, tp, bits, bs, n, n_pad, out_dtype = aux
        return cls(parts=children[0], axis=axis, tp=tp, bits=bits, bs=bs,
                   n=n, n_pad=n_pad, out_dtype=out_dtype)


def ring_start(y: jax.Array, axis: str, spec, tp: int) -> PendingEpilogue:
    """Quantize this rank's partial and issue ring phase 1 — the
    decomposed equivalent of the synchronous strategies' pad/chunk/
    quantize + ``all_to_all`` (numerics copied line-for-line from
    ``comm.dispatch._QuantInt8.apply`` / ``_QuantInt4.apply``)."""
    n = y.shape[-1]
    out_dtype = y.dtype
    y32 = y.astype(jnp.float32)
    pad = (-n) % (tp if spec.bits == 8 else tp * PACK)
    if pad:
        y32 = jnp.pad(y32, [(0, 0)] * (y32.ndim - 1) + [(0, pad)])
    chunk = (n + pad) // tp
    bs = choose_group_size(chunk, spec.block_size)
    yc = jnp.moveaxis(y32.reshape(*y32.shape[:-1], tp, chunk), -2, 0)
    if spec.bits == 8:
        q, s = _blockwise_quantize(yc, bs)
        parts = _rotate_collect((q, s), axis, tp)
    else:
        q, s, z = _blockwise_quantize_int4(yc, bs)
        parts = _rotate_collect((_pack4_last(q), s, z), axis, tp)
    return PendingEpilogue(parts=parts, axis=axis, tp=tp, bits=spec.bits,
                           bs=bs, n=n, n_pad=n + pad, out_dtype=out_dtype)


def ring_start_wire(wp, axis: str, spec, tp: int) -> PendingEpilogue:
    """Issue ring phase 1 directly from a kernel-emitted ``WirePayload``
    (the fused Pallas epilogue already quantized — DESIGN.md §10); the
    reshapes are the same as ``apply_wire``'s."""
    if tp == 1 or tp != wp.tp or wp.bits != spec.bits:
        raise ValueError(
            f"wire payload (tp={wp.tp}, bits={wp.bits}) does not fit a "
            f"{tp}-rank {spec.name} overlapped ring")
    lead = wp.payload.shape[:-1]
    bs = wp.block
    if wp.bits == 8:
        n_pad = wp.payload.shape[-1]
        chunk = n_pad // tp
        q = jnp.moveaxis(wp.payload.reshape(*lead, tp, chunk), -2, 0)
        s = jnp.moveaxis(wp.scales.reshape(*lead, tp, chunk // bs), -2, 0)
        parts = _rotate_collect((q, s), axis, tp)
    else:
        n_pad = wp.payload.shape[-1] * PACK
        words = n_pad // (tp * PACK)
        qp = jnp.moveaxis(wp.payload.reshape(*lead, tp, words), -2, 0)
        s = jnp.moveaxis(
            wp.scales.reshape(*lead, tp, n_pad // (tp * bs)), -2, 0)
        z = jnp.moveaxis(
            wp.zeros.reshape(*lead, tp, n_pad // (tp * bs)), -2, 0)
        parts = _rotate_collect((qp, s, z), axis, tp)
    return PendingEpilogue(parts=parts, axis=axis, tp=tp, bits=wp.bits,
                           bs=bs, n=wp.n, n_pad=n_pad,
                           out_dtype=wp.out_dtype)


def ring_finish(pend: PendingEpilogue) -> jax.Array:
    """Close an in-flight ring: assemble the phase-1 pieces, dequant-
    accumulate the owned chunk (the only f32 arithmetic, same summation
    order as the synchronous ``_exchange``), re-quantize, run the
    decomposed gather phase, and dequantize the assembled result
    locally."""
    if pend.bits == 8:
        q, s = _assemble_collect(pend.parts, pend.axis, pend.tp)
        red = jnp.sum(_blockwise_dequantize(q, s, pend.bs), axis=0)
        q2, s2 = _blockwise_quantize(red, pend.bs)
        qg, sg = _rotate_gather((q2, s2), pend.axis, pend.tp)
        out = _blockwise_dequantize(_merge_last(qg), _merge_last(sg),
                                    pend.bs)
    else:
        qp, s, z = _assemble_collect(pend.parts, pend.axis, pend.tp)
        red = jnp.sum(_blockwise_dequantize_int4(
            _unpack4_last(qp), s, z, pend.bs), axis=0)
        q2, s2, z2 = _blockwise_quantize_int4(red, pend.bs)
        qg, sg, zg = _rotate_gather((_pack4_last(q2), s2, z2),
                                    pend.axis, pend.tp)
        out = _blockwise_dequantize_int4(
            _unpack4_last(_merge_last(qg)), _merge_last(sg),
            _merge_last(zg), pend.bs)
    out = out[..., :pend.n] if pend.n_pad != pend.n else out
    return out.astype(pend.out_dtype)


# ---------------------------------------------------------------------------
# comm-level entry points (decomposed ring, no microbatching)
# ---------------------------------------------------------------------------

def apply_overlapped(y: jax.Array, axis: str, spec, policy=None):
    """Run the decomposed ring back-to-back — what ``comm.apply`` routes
    ``:overlap`` specs to when no GEMM is available to pipeline against
    (bit-identical to the synchronous strategy by construction)."""
    tp = jax.lax.psum(1, axis)
    if tp == 1:
        return y
    return ring_finish(ring_start(y, axis, spec, tp))


def apply_wire_overlapped(wp, axis: str, spec, policy=None):
    """Decomposed ring from a kernel-emitted ``WirePayload``."""
    tp = jax.lax.psum(1, axis)
    return ring_finish(ring_start_wire(wp, axis, spec, tp))


# ---------------------------------------------------------------------------
# the pipelined epilogue (schemes-level entry point)
# ---------------------------------------------------------------------------

def pipelined_epilogue(y1: jax.Array, *, axis: str, spec, gemm,
                       gemm_wire=None) -> jax.Array:
    """Down GEMM + overlapped ring, microbatch-pipelined.

    ``y1`` is the first GEMM's activation (``(..., k)``); ``gemm`` maps a
    row microbatch of it through the down projection to that rank's
    partial output, and ``gemm_wire`` (when the ``:fused`` wire kernel
    applies) maps it to a ``WirePayload`` instead.  The largest leading
    dim is split into two microbatches; each microbatch's ring phase 1
    is issued before the next microbatch's GEMM, and closed only after —
    ``optimization_barrier`` ties make both orderings data dependencies
    (see module doc), so the collectives' windows provably span a
    dequant-GEMM in the scheduled program.  Inputs too small to split
    (no leading dim >= 2) degrade to the unpipelined decomposed ring.
    """
    tp = jax.lax.psum(1, axis)
    if tp == 1:
        # identity collective at TP=1 — the GEMM output unchanged, like
        # every synchronous strategy
        return gemm(y1)

    def start_one(y1_mb, after=None):
        """GEMM the microbatch and issue its ring; ``after`` is the
        previous microbatch's pending ring, returned re-threaded through
        the ordering barriers."""
        if after is not None and jax.default_backend() == "cpu":
            # synchronous-collective backends: pin the previous ring's
            # rotations BEFORE this GEMM (they'd otherwise be free to
            # sink to just before their use).  Skipped on async backends,
            # where this would hold the -done early and kill the overlap.
            y1_mb, after = jax.lax.optimization_barrier((y1_mb, after))
        if gemm_wire is not None:
            out = gemm_wire(y1_mb)
        else:
            out = gemm(y1_mb)
        if after is not None:
            # the previous ring may only close after this GEMM's output
            # exists — the window every scheduler must respect
            out, after = jax.lax.optimization_barrier((out, after))
        pend = (ring_start_wire(out, axis, spec, tp)
                if gemm_wire is not None
                else ring_start(out, axis, spec, tp))
        return pend, after

    split_ax: Optional[int] = None
    if y1.ndim >= 2:
        lead = y1.shape[:-1]
        ax = max(range(len(lead)), key=lambda i: lead[i])
        if lead[ax] >= 2:
            split_ax = ax
    if split_ax is None:
        pend, _ = start_one(y1)
        return ring_finish(pend)

    m0 = y1.shape[split_ax] // 2
    mbs = (jax.lax.slice_in_dim(y1, 0, m0, axis=split_ax),
           jax.lax.slice_in_dim(y1, m0, y1.shape[split_ax], axis=split_ax))
    outs = []
    prev, _ = start_one(mbs[0])
    for y1_mb in mbs[1:]:
        pend, prev = start_one(y1_mb, after=prev)
        outs.append(ring_finish(prev))
        prev = pend
    outs.append(ring_finish(prev))
    return jnp.concatenate(outs, axis=split_ax)
