"""MeshPlan — the device-grid half of the deployment plan.

Mirrors ``comm.CollectiveSpec`` / ``cache.PageSpec``: a tiny frozen,
hashable record with a string shorthand, parsed once at config time and
carried on ``ExecutionPolicy.mesh`` so the launcher, the per-rank
artifact loader, and the ``DeploymentArtifact`` manifest all read one
source of truth about *where* the plan runs.

Shorthands (``parse``/``shorthand`` round-trip exactly)::

    dp1xtp1           single device (the default)
    dp2xtp4           2-way data x 4-way model (tensor) parallel
    dp4xtp2xep2       ... plus 2-way expert parallelism for MoE, carved
                      out of the data axis (ep must divide dp)

The mesh axes are always ``("data", "model")`` — the names every
``shard_map`` in ``models/`` and ``core/schemes.py`` binds to.  EP does
not get its own axis: MoE expert dispatch subgroups the data axis (the
plan records the degree so the artifact can refuse a mismatched
deployment; see DESIGN.md §11).

``build_mesh()`` spans **all** processes' devices (``jax.devices()``,
not ``jax.local_devices()``): under ``jax.distributed.initialize`` each
process sees the same global grid and owns only the rows/columns whose
devices are addressable locally — which is exactly what
``dist/loader.py`` uses to decide which ``rank_NN.npz`` files this
process may read.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Union

__all__ = ["MeshPlan", "local_model_ranks"]

_AXIS_RE = re.compile(r"^(dp|tp|ep)(\d+)$")


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """One DP×TP (optionally ×EP) device grid, fully specified.

    Frozen + hashable: lives on ``ExecutionPolicy`` (a jit static
    argument) and is recorded in the artifact manifest.  ``dp`` is the
    data-parallel degree (the ``"data"`` mesh axis), ``tp`` the
    model/tensor degree (the ``"model"`` axis the row-TP epilogues
    reduce over), ``ep`` an optional expert-parallel degree that must
    divide ``dp``.
    """

    dp: int = 1
    tp: int = 1
    ep: Optional[int] = None

    def __post_init__(self):
        for field in ("dp", "tp"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{field} must be a positive int, got {v!r}")
        if self.ep is not None:
            if not isinstance(self.ep, int) or self.ep < 1:
                raise ValueError(f"ep must be a positive int, got {self.ep!r}")
            if self.dp % self.ep != 0:
                raise ValueError(
                    f"ep={self.ep} must divide dp={self.dp} (expert groups "
                    f"are carved out of the data axis)")

    # ---- construction -----------------------------------------------------

    @classmethod
    def parse(cls, value: Union["MeshPlan", str, None]) -> "MeshPlan":
        """Parse a plan, a ``"dp2xtp4[xep2]"`` shorthand, or None (-> the
        single-device default).  Axis terms may appear in any order but
        each at most once; ``shorthand()`` always prints dp, tp, ep."""
        if value is None:
            return cls()
        if isinstance(value, MeshPlan):
            return value
        if not isinstance(value, str):
            raise TypeError(
                f"expected MeshPlan or string shorthand, "
                f"got {type(value).__name__}")
        seen = {}
        for part in value.split("x"):
            m = _AXIS_RE.match(part)
            if m is None:
                raise ValueError(
                    f"unknown mesh spec {value!r}, expected "
                    f"'dp<N>xtp<M>[xep<K>]' (e.g. 'dp2xtp4')")
            axis, deg = m.group(1), int(m.group(2))
            if axis in seen:
                raise ValueError(
                    f"mesh spec {value!r} repeats the {axis!r} axis")
            seen[axis] = deg
        if "dp" not in seen or "tp" not in seen:
            raise ValueError(
                f"mesh spec {value!r} must name both dp and tp degrees")
        return cls(dp=seen["dp"], tp=seen["tp"], ep=seen.get("ep"))

    def shorthand(self) -> str:
        """The string form ``parse`` round-trips (manifests, CLIs, logs)."""
        s = f"dp{self.dp}xtp{self.tp}"
        if self.ep is not None:
            s += f"xep{self.ep}"
        return s

    def with_(self, **kw) -> "MeshPlan":
        return dataclasses.replace(self, **kw)

    # ---- geometry ---------------------------------------------------------

    @property
    def size(self) -> int:
        """Total devices the plan spans."""
        return self.dp * self.tp

    def build_mesh(self, devices=None):
        """Materialize the (dp, tp) ``("data", "model")`` mesh over the
        global device list (all processes' devices — see module doc)."""
        import jax

        devs = list(jax.devices()) if devices is None else list(devices)
        if len(devs) != self.size:
            raise ValueError(
                f"mesh plan {self.shorthand()} spans {self.size} device(s) "
                f"but {len(devs)} are visible; launch with a matching "
                f"device count (or pass an explicit device subset)")
        import numpy as np

        grid = np.asarray(devs, dtype=object).reshape(self.dp, self.tp)
        return jax.sharding.Mesh(grid, ("data", "model"))

    def local_model_ranks(self, mesh) -> tuple:
        """Model-axis coordinates owned by THIS process's addressable
        devices — the set of ``rank_NN.npz`` files ``dist/loader.py`` is
        allowed to read.  Single-process: every rank."""
        return local_model_ranks(mesh)


def local_model_ranks(mesh) -> tuple:
    """Model-axis ("model", last mesh dim) coordinates of the devices this
    process owns.  Free function so the per-rank loader needs only a mesh,
    not the plan that built it."""
    import jax
    import numpy as np

    pid = jax.process_index()
    ranks = set()
    grid = np.asarray(mesh.devices, dtype=object)
    for idx, dev in np.ndenumerate(grid):
        if dev.process_index == pid:
            ranks.add(int(idx[-1]))
    return tuple(sorted(ranks))
