"""Per-rank artifact loading: each process reads only its own shards.

``DeploymentArtifact.load`` reads every ``rank_NN.npz`` and (for mesh
serving) reassembles the global pytree on the host — fine for a single
process that owns the whole mesh, wasteful-to-impossible once the mesh
spans processes: a host would materialize TP-degree times the weights it
can actually place, and at full-model scale wouldn't fit.

``load_per_rank`` is the distributed path.  For a ``("data", "model")``
mesh it:

1. asks ``topology.local_model_ranks`` which model-axis coordinates this
   process's addressable devices sit on,
2. ``checkpoint.load``\\ s exactly those ``rank_NN.npz`` files — the other
   ranks' files are *stat*-ed for the byte ledger but never opened,
3. assembles each leaf as a global ``jax.Array`` from per-device
   addressable shards via ``jax.make_array_from_single_device_arrays``:
   a leaf pre-split along dim ``d`` (the manifest's ``leaf_shards``)
   gets ``NamedSharding(mesh, P(..., "model" @ d, ...))`` with device
   ``(i, j)`` holding rank ``j``'s slice verbatim; an unsplit leaf is
   replicated (``P()``) from the lowest local rank's copy.

Because rank ``j``'s file *is* the ``j``-th slice of every split leaf
(``plan/compiler.stage_shard`` wrote it that way), placement is pure
``device_put`` — no slicing, no concatenation, and crucially no host
copy of any rank this process doesn't own.  The sharding matches
``schemes.pair_pspecs``, so ``shard_map`` consumes the arrays in place.

``RankLoadStats`` is the proof: ``file_bytes_loaded`` (disk bytes this
process read) vs ``file_bytes_total`` (all rank files, sizes via
``os.path.getsize`` only) — a multi-process launch asserts strictly
less-than; the serve banner prints both.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.topology import local_model_ranks

__all__ = ["RankLoadStats", "load_per_rank", "rank_file"]


@dataclasses.dataclass(frozen=True)
class RankLoadStats:
    """What this process actually read off disk (see module doc)."""

    ranks: tuple                 # model-axis ranks whose files were read
    bytes_loaded: int            # sum of leaf nbytes across those files
    file_bytes_loaded: int       # on-disk bytes of the files read
    file_bytes_total: int        # on-disk bytes of ALL rank files

    @property
    def resident_fraction(self) -> float:
        if not self.file_bytes_total:
            return 1.0
        return self.file_bytes_loaded / self.file_bytes_total


def rank_file(dirpath: str, r: int) -> str:
    return os.path.join(dirpath, f"rank_{r:02d}.npz")


def load_per_rank(dirpath: str, manifest: dict,
                  mesh: jax.sharding.Mesh) -> tuple[Any, RankLoadStats]:
    """Load a prepared artifact directory for ``mesh``, reading only this
    process's rank files.  Returns ``(params, stats)`` where ``params`` is
    the planned pytree with every leaf a global ``jax.Array`` sharded (or
    replicated) over ``mesh``.
    """
    from repro.train import checkpoint

    tp = int(manifest["tp"])
    model_dim = mesh.devices.shape[-1]
    if model_dim != tp:
        raise ValueError(
            f"mesh model-axis degree {model_dim} != artifact TP {tp}; "
            "re-run prepare for this mesh")

    ranks = local_model_ranks(mesh)
    if not ranks:
        raise RuntimeError(
            f"process {jax.process_index()} owns no devices on this mesh")
    missing = [r for r in range(tp)
               if not os.path.exists(rank_file(dirpath, r))]
    if missing:
        raise FileNotFoundError(
            f"{dirpath} is missing rank files {missing} (artifact was "
            f"prepared for tp={tp})")

    trees = {r: checkpoint.load(rank_file(dirpath, r)) for r in ranks}
    flats = {r: checkpoint.flatten_keys(t) for r, t in trees.items()}
    r0 = ranks[0]
    shards = manifest["leaf_shards"]

    # addressable (device, model-coord) pairs: device grid column j holds
    # rank j's slice of every split leaf (replicated along the data axis)
    pid = jax.process_index()
    grid = np.asarray(mesh.devices, dtype=object)
    addr = [(dev, int(idx[-1])) for idx, dev in np.ndenumerate(grid)
            if dev.process_index == pid]

    leaves = []
    for key, leaf0 in flats[r0].items():
        dim = shards.get(key)
        lshape = tuple(np.shape(leaf0))
        if dim is None:
            gshape = lshape
            sharding = NamedSharding(mesh, P())
            arrs = [jax.device_put(leaf0, dev) for dev, _ in addr]
        else:
            dim = int(dim)
            gshape = lshape[:dim] + (lshape[dim] * tp,) + lshape[dim + 1:]
            spec = [None] * len(lshape)
            spec[dim] = "model"
            sharding = NamedSharding(mesh, P(*spec))
            arrs = [jax.device_put(flats[j][key], dev) for dev, j in addr]
        leaves.append(jax.make_array_from_single_device_arrays(
            gshape, sharding, arrs))

    # flatten_keys iterates in tree_flatten leaf order, so unflattening
    # through the local tree's structure reproduces the planned pytree
    treedef = jax.tree_util.tree_structure(trees[r0])
    params = jax.tree_util.tree_unflatten(treedef, leaves)

    stats = RankLoadStats(
        ranks=ranks,
        bytes_loaded=sum(int(v.nbytes)
                         for f in flats.values() for v in f.values()),
        file_bytes_loaded=sum(os.path.getsize(rank_file(dirpath, r))
                              for r in ranks),
        file_bytes_total=sum(os.path.getsize(rank_file(dirpath, r))
                             for r in range(tp)))
    return params, stats
