"""WirePayload — a pre-quantized TP epilogue payload (DESIGN.md §10).

The quantized collectives normally quantize ``y_partial`` themselves
(phase 1 of the two-phase ring in ``comm/dispatch.py``).  The fused
Pallas kernels (``kernels/dequant_matmul.dequant_matmul_wire_ordered``)
emit that exact payload straight from the GEMM accumulator tiles, so the
dense partial never round-trips HBM.  This module holds the contract
between the two layers:

* ``wire_params(n, tp, bits, preferred_block)`` — the padding / chunking
  / quant-block geometry the ring uses for a width-``n`` output.  Both
  the kernel wrapper and the collective derive their shapes from this
  one function, so the flat kernel output reshapes bit-exactly into the
  ring's chunked form.
* ``WirePayload`` — the kernel's output: a FLAT payload over the padded
  width ``n_pad`` (int8 values, or nibble-packed uint32 words for int4)
  plus f16 scales (and zeros for int4), with the static geometry the
  collective needs to chunk it (``n``, ``tp``, ``bits``, ``block``) and
  the dtype the result must be cast back to (``out_dtype`` — the wire
  never leaks into the residual stream).

Flat -> chunked equivalence: the ring quantizes ``tp`` chunks of width
``chunk = n_pad / tp`` with blocks of size ``block`` where
``block | chunk`` (and ``8 | chunk`` for int4 packing), so neither a
quant block nor a packed word ever straddles a chunk boundary — a plain
``reshape(..., tp, chunk) -> moveaxis(-2, 0)`` of the flat payload IS
the chunked phase-1 payload, bit for bit.

Lives in ``comm`` (not ``kernels``) so ``kernels/dispatch.py`` can
import it without a cycle: ``comm`` never imports ``kernels``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax

from repro.core.quantization import PACK, choose_group_size

__all__ = ["WirePayload", "wire_params"]


def wire_params(n: int, tp: int, bits: int,
                preferred_block: int) -> tuple[int, int, int]:
    """``(n_pad, chunk, block)`` for the two-phase quantized ring over a
    width-``n`` row-TP output: the zero-padded wire width (whole chunks
    per rank; whole uint32 words per chunk for int4), the per-rank chunk,
    and the quant block actually used (largest divisor of ``chunk`` at
    most ``preferred_block`` — exactly ``choose_group_size``, matching
    ``comm/dispatch._QuantInt8/_QuantInt4.apply``)."""
    pad_to = tp * (PACK if bits == 4 else 1)
    n_pad = n + (-n) % pad_to
    chunk = n_pad // tp
    return n_pad, chunk, choose_group_size(chunk, preferred_block)


@dataclasses.dataclass
class WirePayload:
    """One rank's pre-quantized partial, ready for ring phase 1.

    ``payload`` is flat over the padded width: ``(..., n_pad)`` int8 for
    8-bit wires, ``(..., n_pad // 8)`` uint32 (``pack_int4`` nibble
    layout) for 4-bit.  ``scales`` (and ``zeros``, int4 only) are
    ``(..., n_pad // block)`` f16.  The non-array fields are static
    geometry (see ``wire_params``)."""

    payload: jax.Array
    scales: jax.Array
    zeros: Optional[jax.Array]
    n: int                  # logical (un-padded) output width
    tp: int                 # ring size the payload was padded for
    bits: int               # 8 or 4
    block: int              # quant block actually used
    out_dtype: Any          # dtype the collective result is cast back to

    @property
    def n_pad(self) -> int:
        w = self.payload.shape[-1]
        return w * PACK if self.bits == 4 else w


jax.tree_util.register_pytree_node(
    WirePayload,
    lambda wp: ((wp.payload, wp.scales, wp.zeros),
                (wp.n, wp.tp, wp.bits, wp.block, wp.out_dtype)),
    lambda aux, children: WirePayload(*children, *aux),
)
