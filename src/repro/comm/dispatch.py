"""Collective dispatch: ``CollectiveSpec.name`` -> TP epilogue strategy.

Mirror of ``kernels/dispatch.py`` for the *communication* half of the
deployment plan: this registry is the ONLY place in the repo that maps
collective names to implementations.  ``schemes._pair_local_forward``
(and therefore every TP scheme forward, model MLP, and serving path)
closes its row-TP layer here from the ``ExecutionPolicy.collective``
spec; new strategies register themselves with the ``@register`` decorator
and immediately become valid spec names — no stringly-typed branching at
the call sites.

Strategy contract (``y_partial`` is one rank's full-size partial sum of
the row-TP output, executing inside ``shard_map`` over mesh axis
``axis``):

* ``apply(y_partial, axis, spec, policy) -> y`` — run the collective.
  **Dtype contract**: the result dtype is the INPUT dtype for every
  strategy — wire dtypes (bf16 words, int8/int4 payloads) never leak
  into the caller's residual stream, and at ``tp == 1`` every strategy
  is the identity.  (``cast`` historically returned its wire dtype,
  which compounded bf16 rounding per layer in an f32 stream — fixed,
  see ``_Cast``.)
* ``bytes_on_wire(shape, tp, spec) -> float`` — analytic per-device ICI
  bytes under the same ring cost model as ``launch/roofline.py``, so
  ``bench_comm`` accounts each strategy without compiling it,
* ``scatters_output`` — True when the result stays sharded along its
  last dim (the caller's out_specs must match).

Seed strategies (see DESIGN.md §1):

* ``psum``         — f32 all-reduce; bit-exact with the historical path.
* ``psum_scatter`` — reduce-scatter; output sharded, half the ICI bytes.
* ``cast``         — all-reduce in a low-bit wire dtype (default bf16);
  absorbs the old ad-hoc ``reduce_dtype`` cast.
* ``quant-int8``   — blockwise symmetric int8 quantized all-reduce
  (quantize -> exchange int8 payloads + f16 scales -> local
  dequant-accumulate), after Hansen-Palmus et al. 2024 / Dong et
  al. 2024: ~4x fewer wire bytes than f32 ``psum``.
* ``quant-int4``   — blockwise asymmetric int4: the wire payload is the
  weights' own storage format (``quantization.pack_int4``, 8 nibbles per
  uint32) plus f16 scale+zero per block — ~8x fewer payload bytes than
  f32 ``psum`` (``bench_comm``'s strategy table reports the measured and
  analytic bytes alongside the other registry entries).
* ``none``         — no collective: the paper's TP-aware
  gather-elimination made explicit (caller handles the partials).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm.spec import CollectiveSpec
from repro.core.quantization import (PACK, choose_group_size, pack_int4,
                                     unpack_int4)

_REGISTRY: dict[str, "CollectiveStrategy"] = {}


class CollectiveStrategy:
    """Base class: one named way to close a row-TP layer."""

    #: True when ``apply`` returns a result sharded along its last dim.
    scatters_output: bool = False

    #: True when ``apply_wire`` accepts a kernel-emitted ``WirePayload``
    #: (the fused Pallas epilogue of DESIGN.md §10).
    accepts_wire: bool = False

    def apply(self, y: jax.Array, axis: str, spec: CollectiveSpec,
              policy) -> jax.Array:
        raise NotImplementedError

    def apply_wire(self, wp, axis: str, spec: CollectiveSpec,
                   policy) -> jax.Array:
        raise NotImplementedError(
            f"collective {spec.name!r} does not accept a pre-quantized "
            f"wire payload")

    def bytes_on_wire(self, shape: tuple, tp: int,
                      spec: CollectiveSpec) -> float:
        raise NotImplementedError


def register(name: str):
    """Decorator: register a ``CollectiveStrategy`` subclass under ``name``."""

    def deco(cls):
        _REGISTRY[name] = cls()
        return cls

    return deco


def strategies() -> tuple[str, ...]:
    """Registered collective strategy names."""
    return tuple(sorted(_REGISTRY))


def resolve(name: str) -> CollectiveStrategy:
    """Look up the strategy for a collective name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"no collective strategy registered for {name!r}; "
            f"registered strategies: {list(strategies())}") from None


def apply(y: jax.Array, axis: str, spec: CollectiveSpec, policy=None):
    """Close a row-TP layer: run ``spec`` on one rank's partial sums.
    ``:overlap`` quant specs route to the decomposed ``ppermute`` ring
    (``dist/overlap.py``) — bit-identical, same wire bytes, but issued
    as rotations the scheduler can hide behind compute."""
    if spec.overlap:
        from repro.dist import overlap as _overlap  # deferred: dist imports us
        return _overlap.apply_overlapped(y, axis, spec, policy)
    return resolve(spec.name).apply(y, axis, spec, policy)


def apply_wire(wp, axis: str, spec: CollectiveSpec, policy=None):
    """Close a row-TP layer from a kernel-emitted ``WirePayload``: the
    fused Pallas epilogue already ran ring phase 1's quantize, so the
    collective starts directly at the payload exchange (DESIGN.md §10)."""
    if spec.overlap:
        from repro.dist import overlap as _overlap
        return _overlap.apply_wire_overlapped(wp, axis, spec, policy)
    return resolve(spec.name).apply_wire(wp, axis, spec, policy)


def accepts_wire(spec: CollectiveSpec) -> bool:
    return resolve(spec.name).accepts_wire


def scatters_output(spec: CollectiveSpec) -> bool:
    return resolve(spec.name).scatters_output


def bytes_on_wire(spec: CollectiveSpec, shape, tp: int) -> float:
    return resolve(spec.name).bytes_on_wire(tuple(shape), int(tp), spec)


# ---------------------------------------------------------------------------
# raw-primitive facade
# ---------------------------------------------------------------------------
# The only sanctioned spellings of ``jax.lax`` collectives outside comm/
# and dist/ (``repro.analysis.ast_lint`` rule AS001): scheme and model
# code goes through these wrappers, so every cross-rank byte traces to a
# site the roofline cost model and the plan compiler account for.

def axis_size(axis: str) -> int:
    """Ring size of a named mesh axis (``lax.psum(1, axis)``)."""
    return jax.lax.psum(1, axis)


def raw_psum(y: jax.Array, axis: str) -> jax.Array:
    """Full-precision all-reduce outside the strategy registry — for
    epilogues whose output contract is structural (e.g. MoE within-expert
    reduction), not a tunable quality/bytes trade-off."""
    return jax.lax.psum(y, axis)


def all_gather_cols(y: jax.Array, axis: str) -> jax.Array:
    """Gather last-dim shards into the full tensor (tiled) — the naive
    scheme's Algorithm-2 line-2 gather."""
    return jax.lax.all_gather(y, axis, axis=y.ndim - 1, tiled=True)


def all_to_all(x: jax.Array, axis: str, *, split_axis: int,
               concat_axis: int) -> jax.Array:
    """Tiled all_to_all (the MoE dispatch/return token shuffle)."""
    return jax.lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _full_bytes(shape, dtype) -> float:
    return math.prod(shape) * jnp.dtype(dtype).itemsize


def _wire_dtype(spec: CollectiveSpec):
    return spec.wire_dtype if spec.wire_dtype is not None else jnp.float32


def _blockwise_quantize(v: jax.Array, bs: int):
    """Symmetric int8 quantization over size-``bs`` blocks of the last dim.

    Returns ``(q int8 same-shape, scales f16 (..., n // bs))`` — the two
    wire payloads of the compressed collectives.
    """
    vb = v.reshape(*v.shape[:-1], v.shape[-1] // bs, bs)
    s = jnp.max(jnp.abs(vb), axis=-1) / 127.0
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(vb / s[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(v.shape), s.astype(jnp.float16)


def _blockwise_dequantize(q: jax.Array, s: jax.Array, bs: int) -> jax.Array:
    qb = q.reshape(*q.shape[:-1], q.shape[-1] // bs, bs).astype(jnp.float32)
    return (qb * s.astype(jnp.float32)[..., None]).reshape(q.shape)


# ---------------------------------------------------------------------------
# seed strategies
# ---------------------------------------------------------------------------

@register("psum")
class _Psum(CollectiveStrategy):
    """Full-precision all-reduce — bit-exact with ``jax.lax.psum``."""

    def apply(self, y, axis, spec, policy):
        return jax.lax.psum(y, axis)

    def bytes_on_wire(self, shape, tp, spec):
        return _full_bytes(shape, _wire_dtype(spec)) * 2 * (tp - 1) / tp


@register("psum_scatter")
class _PsumScatter(CollectiveStrategy):
    """Reduce-scatter along the output dim; the caller keeps the output
    sharded (half the ICI bytes of an all-reduce)."""

    scatters_output = True

    def apply(self, y, axis, spec, policy):
        return jax.lax.psum_scatter(y, axis, scatter_dimension=y.ndim - 1,
                                    tiled=True)

    def bytes_on_wire(self, shape, tp, spec):
        return _full_bytes(shape, _wire_dtype(spec)) * (tp - 1) / tp


@register("cast")
class _Cast(CollectiveStrategy):
    """All-reduce in a low-bit wire dtype (default bf16): the per-rank f32
    partial sums are already complete, so only the cross-rank accumulation
    is lower-precision.  The result is cast BACK to the input dtype — the
    wire dtype is a transport detail, not an output contract (returning
    bf16 into an f32 residual stream silently downgraded every subsequent
    layer, compounding per layer; the quantized strategies already
    restored ``y.dtype``, so this makes the contract uniform)."""

    def apply(self, y, axis, spec, policy):
        if jax.lax.psum(1, axis) == 1:
            return y
        return jax.lax.psum(y.astype(spec.wire_dtype), axis).astype(y.dtype)

    def bytes_on_wire(self, shape, tp, spec):
        return _full_bytes(shape, spec.wire_dtype) * 2 * (tp - 1) / tp


@register("none")
class _NoCollective(CollectiveStrategy):
    """No epilogue collective: return this rank's partial sums.  The
    paper's TP-aware gather-elimination made explicit — used when the
    caller fuses the reduction into a later op (or measures compute
    alone)."""

    def apply(self, y, axis, spec, policy):
        return y

    def bytes_on_wire(self, shape, tp, spec):
        return 0.0


@register("quant-int8")
class _QuantInt8(CollectiveStrategy):
    """Blockwise-int8 quantized all-reduce (communication compression).

    Both phases of the ring all-reduce carry int8 payloads + f16 scales
    instead of f32 words (Hansen-Palmus et al. 2024; Dong et al. 2024):

    1. chunk the local partial along the output dim into ``tp`` pieces,
       quantize blockwise, ``all_to_all`` so each rank receives every
       rank's int8 copy of the chunk it owns,
    2. dequant-accumulate the owned chunk in f32 (the only full-precision
       arithmetic — quantization error does not compound across ranks),
    3. re-quantize the reduced chunk and ``all_gather`` payloads + scales;
       every rank dequantizes the assembled result locally.

    When the output dim does not tile ``tp``, the partial is zero-padded
    on the wire up to the next multiple of ``tp`` and sliced after — the
    SAME two-phase ring runs for every shape.  (The old one-phase
    fallback all-gathered every rank's full-size payload, ``payload *
    (tp - 1)`` per-device bytes vs the ring's ``2 * payload *
    (tp - 1) / tp`` — up to ``tp/2``× the wire traffic — while
    ``bytes_on_wire`` charged the two paths inconsistently, inflating
    ``bench_comm`` vs_psum ratios on non-tiling dims.  Both the
    implementation and the accounting are now the ring model.)
    """

    accepts_wire = True

    @staticmethod
    def _exchange(q, s, axis, bs):
        """Both ring phases from the chunked phase-1 payload ``(tp, ...,
        chunk)``: exchange, dequant-accumulate, re-quantize, gather,
        local dequantize.  Shared by ``apply`` and ``apply_wire``."""
        q = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0,
                               tiled=True)
        s = jax.lax.all_to_all(s, axis, split_axis=0, concat_axis=0,
                               tiled=True)
        red = jnp.sum(_blockwise_dequantize(q, s, bs), axis=0)
        q2, s2 = _blockwise_quantize(red, bs)
        qg = jax.lax.all_gather(q2, axis, axis=q2.ndim - 1, tiled=True)
        sg = jax.lax.all_gather(s2, axis, axis=s2.ndim - 1, tiled=True)
        return _blockwise_dequantize(qg, sg, bs)

    def apply(self, y, axis, spec, policy):
        tp = jax.lax.psum(1, axis)
        if tp == 1:
            return y
        n = y.shape[-1]
        out_dtype = y.dtype
        y32 = y.astype(jnp.float32)
        pad = (-n) % tp
        if pad:
            y32 = jnp.pad(y32, [(0, 0)] * (y32.ndim - 1) + [(0, pad)])
        chunk = (n + pad) // tp
        bs = choose_group_size(chunk, spec.block_size)
        yc = jnp.moveaxis(y32.reshape(*y32.shape[:-1], tp, chunk), -2, 0)
        q, s = _blockwise_quantize(yc, bs)
        out = self._exchange(q, s, axis, bs)
        return (out[..., :n] if pad else out).astype(out_dtype)

    def apply_wire(self, wp, axis, spec, policy):
        tp = jax.lax.psum(1, axis)
        if tp == 1 or tp != wp.tp or wp.bits != 8:
            raise ValueError(
                f"wire payload (tp={wp.tp}, bits={wp.bits}) does not fit "
                f"a {tp}-rank {spec.name} ring")
        lead = wp.payload.shape[:-1]
        n_pad = wp.payload.shape[-1]
        chunk = n_pad // tp
        bs = wp.block
        # the flat payload chunks exactly (bs | chunk), so this reshape
        # IS ring phase 1's quantized form — see comm/wire.py.
        q = jnp.moveaxis(wp.payload.reshape(*lead, tp, chunk), -2, 0)
        s = jnp.moveaxis(wp.scales.reshape(*lead, tp, chunk // bs), -2, 0)
        out = self._exchange(q, s, axis, bs)
        return (out[..., :wp.n] if n_pad != wp.n else out).astype(
            wp.out_dtype)

    def bytes_on_wire(self, shape, tp, spec):
        if tp <= 1:
            return 0.0
        n_pad = shape[-1] + (-shape[-1]) % tp      # zero-padded on the wire
        n_elts = math.prod(shape[:-1]) * n_pad
        bs = choose_group_size(n_pad // tp, spec.block_size)
        payload = n_elts * 1 + (n_elts / bs) * 2   # int8 + f16 scales
        # all_to_all phase + all_gather phase, each (tp-1)/tp of payload
        return 2 * payload * (tp - 1) / tp


# ---------------------------------------------------------------------------
# int4 payload (packed like the weights)
# ---------------------------------------------------------------------------

def _pack4_last(q: jax.Array) -> jax.Array:
    """Pack int values in [0, 15] along the LAST dim via the weights'
    ``pack_int4`` layout (8 nibbles per uint32): (..., n) -> (..., n//8)."""
    moved = jnp.moveaxis(q, -1, 0)                        # (n, ...)
    flat = moved.reshape(moved.shape[0], -1)              # (n, rest)
    packed = pack_int4(flat)                              # (n//8, rest)
    return jnp.moveaxis(packed.reshape(moved.shape[0] // PACK,
                                       *moved.shape[1:]), 0, -1)


def _unpack4_last(qp: jax.Array) -> jax.Array:
    """Inverse of ``_pack4_last``: (..., n//8) uint32 -> (..., n) int32."""
    moved = jnp.moveaxis(qp, -1, 0)                       # (n//8, ...)
    flat = moved.reshape(moved.shape[0], -1)
    vals = unpack_int4(flat)                              # (n, rest)
    return jnp.moveaxis(vals.reshape(moved.shape[0] * PACK,
                                     *moved.shape[1:]), 0, -1)


def _blockwise_quantize_int4(v: jax.Array, bs: int):
    """Asymmetric int4 quantization over size-``bs`` blocks of the last
    dim — the same min/max formulation the weight quantizer uses.

    Returns ``(q int32 in [0,15] same-shape, scales f16 (..., n // bs),
    zeros f16)``."""
    vb = v.reshape(*v.shape[:-1], v.shape[-1] // bs, bs)
    vmax = jnp.maximum(jnp.max(vb, axis=-1), 0.0)
    vmin = jnp.minimum(jnp.min(vb, axis=-1), 0.0)
    s = (vmax - vmin) / 15.0
    s = jnp.where(s <= 0, 1.0, s)
    z = jnp.clip(jnp.round(-vmin / s), 0, 15)
    q = jnp.clip(jnp.round(vb / s[..., None] + z[..., None]), 0, 15)
    return (q.astype(jnp.int32).reshape(v.shape),
            s.astype(jnp.float16), z.astype(jnp.float16))


def _blockwise_dequantize_int4(q: jax.Array, s: jax.Array, z: jax.Array,
                               bs: int) -> jax.Array:
    qb = q.reshape(*q.shape[:-1], q.shape[-1] // bs, bs).astype(jnp.float32)
    s32 = s.astype(jnp.float32)[..., None]
    z32 = z.astype(jnp.float32)[..., None]
    return ((qb - z32) * s32).reshape(q.shape)


@register("quant-int4")
class _QuantInt4(CollectiveStrategy):
    """Blockwise-int4 quantized all-reduce (the ROADMAP PR-2 follow-up).

    Same two-phase ring structure as ``quant-int8``, but the wire payload
    is nibble-packed with the weights' own storage format
    (``quantization.pack_int4``: 8 values per uint32) plus an f16
    (scale, zero) pair per block — asymmetric, because 15 levels waste
    too much range on the symmetric variant's unused negative tail.
    When the output dim does not tile ``tp * 8`` (packing needs whole
    uint32 words per chunk), the partial is zero-padded on the wire up
    to the next such multiple and sliced after — the same padded ring
    (and the same ring ``bytes_on_wire`` accounting) as ``quant-int8``;
    the old full-payload one-phase all-gather fallback is gone.
    """

    accepts_wire = True

    @staticmethod
    def _exchange(qp, s, z, axis, bs):
        """Both ring phases from the chunked packed phase-1 payload
        ``(tp, ..., chunk//8)`` — shared by ``apply`` and
        ``apply_wire``."""
        qp = jax.lax.all_to_all(qp, axis, split_axis=0, concat_axis=0,
                                tiled=True)
        s = jax.lax.all_to_all(s, axis, split_axis=0, concat_axis=0,
                               tiled=True)
        z = jax.lax.all_to_all(z, axis, split_axis=0, concat_axis=0,
                               tiled=True)
        red = jnp.sum(_blockwise_dequantize_int4(
            _unpack4_last(qp), s, z, bs), axis=0)
        q2, s2, z2 = _blockwise_quantize_int4(red, bs)
        qp2 = _pack4_last(q2)
        qg = jax.lax.all_gather(qp2, axis, axis=qp2.ndim - 1, tiled=True)
        sg = jax.lax.all_gather(s2, axis, axis=s2.ndim - 1, tiled=True)
        zg = jax.lax.all_gather(z2, axis, axis=z2.ndim - 1, tiled=True)
        return _blockwise_dequantize_int4(_unpack4_last(qg), sg, zg, bs)

    def apply(self, y, axis, spec, policy):
        tp = jax.lax.psum(1, axis)
        if tp == 1:
            return y
        n = y.shape[-1]
        out_dtype = y.dtype
        y32 = y.astype(jnp.float32)
        pad = (-n) % (tp * PACK)
        if pad:
            y32 = jnp.pad(y32, [(0, 0)] * (y32.ndim - 1) + [(0, pad)])
        chunk = (n + pad) // tp
        bs = choose_group_size(chunk, spec.block_size)
        yc = jnp.moveaxis(y32.reshape(*y32.shape[:-1], tp, chunk), -2, 0)
        q, s, z = _blockwise_quantize_int4(yc, bs)
        out = self._exchange(_pack4_last(q), s, z, axis, bs)
        return (out[..., :n] if pad else out).astype(out_dtype)

    def apply_wire(self, wp, axis, spec, policy):
        tp = jax.lax.psum(1, axis)
        if tp == 1 or tp != wp.tp or wp.bits != 4:
            raise ValueError(
                f"wire payload (tp={wp.tp}, bits={wp.bits}) does not fit "
                f"a {tp}-rank {spec.name} ring")
        lead = wp.payload.shape[:-1]
        n_pad = wp.payload.shape[-1] * PACK
        bs = wp.block
        # packed words never straddle chunk boundaries (8 | chunk), so
        # the flat word array chunks exactly — see comm/wire.py.
        words = n_pad // (tp * PACK)
        qp = jnp.moveaxis(wp.payload.reshape(*lead, tp, words), -2, 0)
        s = jnp.moveaxis(
            wp.scales.reshape(*lead, tp, n_pad // (tp * bs)), -2, 0)
        z = jnp.moveaxis(
            wp.zeros.reshape(*lead, tp, n_pad // (tp * bs)), -2, 0)
        out = self._exchange(qp, s, z, axis, bs)
        return (out[..., :wp.n] if n_pad != wp.n else out).astype(
            wp.out_dtype)

    def bytes_on_wire(self, shape, tp, spec):
        if tp <= 1:
            return 0.0
        n = shape[-1]
        n_pad = n + (-n) % (tp * PACK)             # whole words per chunk
        n_elts = math.prod(shape[:-1]) * n_pad
        bs = choose_group_size(n_pad // tp, spec.block_size)
        # nibble-packed payload + f16 (scale, zero) per block
        payload = n_elts * 0.5 + (n_elts / bs) * 4
        return 2 * payload * (tp - 1) / tp
