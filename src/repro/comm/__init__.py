"""TP epilogue collectives: spec (``CollectiveSpec``) + strategy registry
(``comm/dispatch.py``).  See DESIGN.md §1 for the architecture."""

from repro.comm.spec import CollectiveSpec
from repro.comm import dispatch

__all__ = ["CollectiveSpec", "dispatch"]
