"""TP epilogue collectives: spec (``CollectiveSpec``), per-layer plan
(``CollectivePlan``) + strategy registry (``comm/dispatch.py``).  See
DESIGN.md §1 and §7 for the architecture."""

from repro.comm.spec import CollectivePlan, CollectiveSpec, parse_collective
from repro.comm import dispatch

__all__ = ["CollectivePlan", "CollectiveSpec", "parse_collective",
           "dispatch"]
