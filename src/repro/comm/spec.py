"""CollectiveSpec / CollectivePlan — TP epilogue collectives as a plan.

The paper's speedup is a *communication* plan decided a priori: TP-Aware
pays only the trailing AllReduce while the Naive Algorithm's AllGather
grows with rank count.  This module names that trailing collective as a
frozen, hashable spec — strategy name, wire dtype, and quantization
parameters — so the whole comm plan travels on the ``ExecutionPolicy``
exactly like the kernel plan does, and compressed collectives
(Hansen-Palmus et al. 2024; Dong et al. 2024) are one registry entry
away instead of a new string branch at every call site.

``CollectiveSpec.parse`` accepts the string shorthands used by configs
and CLIs:

* ``"psum"`` / ``"psum_scatter"`` / ``"none"`` — bit-exact strategies,
* ``"cast"`` or ``"cast:<dtype>"`` — low-bit wire dtype (default bf16),
* ``"quant-int8"`` or ``"quant-int8:<block>"`` — blockwise int8
  quantized all-reduce (block size default 128),
* ``"quant-int4"`` or ``"quant-int4:<block>"`` — blockwise int4: the
  payload is packed 8-nibbles-per-uint32 with the same
  ``quantization.pack_int4`` layout the weights use (block default 32 —
  15 levels need tighter blocks than int8's 255).
* Either quant shorthand takes a trailing ``:fused`` flag
  (``"quant-int8:128:fused"``) — the wire payload is emitted directly
  from the Pallas dequant-GEMM accumulator tiles instead of a separate
  quantize pass over ``y_partial`` (DESIGN.md §10); bit-identical on the
  wire, so ``bytes_on_wire`` is unchanged.
* ... and a trailing ``:overlap`` flag (``"quant-int8:128:fused:overlap"``;
  flag order is accepted either way, the shorthand prints ``:fused``
  first) — the two-phase ring is decomposed into explicit ``ppermute``
  rotations and microbatch-pipelined against the down GEMM
  (``dist/overlap.py``, DESIGN.md §11); bit-identical output and
  identical wire bytes, only the *exposure* of the collective changes.

``CollectivePlan`` lifts the spec to a *per-layer* decision (tolerance
to wire compression varies sharply by layer — Hansen-Palmus et al.
2024; Dong et al. 2024): an ordered ``(path glob, CollectiveSpec)`` map
plus a default, resolved per pair path at the epilogue.  The CLI/config
shorthand is ``"per-layer:<glob>=<spec>[,...][,*=<default>]"``, e.g.
``"per-layer:*.mlp=quant-int8:128,attn*=cast:bf16,*=psum"``; a bare
``CollectiveSpec`` still works everywhere as a one-entry plan
(``parse_collective`` keeps both forms first-class).

Strategy *implementations* live in ``comm/dispatch.py``; the spec only
describes the plan.  ``spec.bytes_on_wire(shape, tp)`` resolves the
strategy's analytic per-device ICI cost so benchmarks and the roofline
can account communication per strategy without compiling anything.
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

__all__ = ["CollectiveSpec", "CollectivePlan", "parse_collective"]

_WIRE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "float16": jnp.float16,
                # CLI-friendly aliases (the canonical shorthand always
                # prints the full dtype name)
                "f32": jnp.float32, "fp32": jnp.float32,
                "bf16": jnp.bfloat16,
                "f16": jnp.float16, "fp16": jnp.float16}


def _canon_wire_dtype(dt):
    """Canonicalize a wire dtype-like (string names allowed; None passes)."""
    if dt is None:
        return None
    if isinstance(dt, str):
        try:
            dt = _WIRE_DTYPES[dt]
        except KeyError:
            raise ValueError(
                f"unknown wire dtype {dt!r}, expected one of "
                f"{sorted(_WIRE_DTYPES)}") from None
    return jax.dtypes.canonicalize_dtype(dt)


@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """One TP epilogue collective, fully specified.

    Frozen + hashable: lives inside ``ExecutionPolicy`` (a jit static
    argument).  ``name`` is a key into the ``comm/dispatch.py`` registry;
    the remaining fields parameterize the strategy:

    * ``wire_dtype`` — the dtype that crosses the ICI (``cast``; also the
      dtype ``bytes_on_wire`` assumes for uncompressed strategies, f32
      when None),
    * ``block_size`` / ``bits`` — blockwise quantization parameters for
      the compressed strategies (``quant-int8``).
    """

    name: str = "psum"
    wire_dtype: Optional[Any] = None
    block_size: int = 128
    bits: Optional[int] = None   # None -> the strategy's payload width
    fused: bool = False          # wire payload produced by the GEMM kernel
    overlap: bool = False        # decomposed ring pipelined with the GEMM

    def __post_init__(self):
        from repro.comm import dispatch  # deferred: dispatch imports spec
        if self.name not in dispatch.strategies():
            raise ValueError(
                f"unknown collective {self.name!r}; registered strategies: "
                f"{list(dispatch.strategies())}")
        if self.name == "cast" and self.wire_dtype is None:
            object.__setattr__(self, "wire_dtype", jnp.bfloat16)
        if self.bits is None:
            object.__setattr__(self, "bits",
                               4 if self.name == "quant-int4" else 8)
        object.__setattr__(self, "wire_dtype",
                           _canon_wire_dtype(self.wire_dtype))
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive, "
                             f"got {self.block_size}")
        if self.bits not in (4, 8):
            raise ValueError(
                f"only 4/8-bit payloads are implemented, got "
                f"bits={self.bits}")
        want_bits = {"quant-int8": 8, "quant-int4": 4}.get(self.name)
        if want_bits is not None and self.bits != want_bits:
            raise ValueError(
                f"{self.name} carries {want_bits}-bit payloads, got "
                f"bits={self.bits}")
        if self.fused and self.name not in ("quant-int8", "quant-int4"):
            raise ValueError(
                f"fused wire epilogue only applies to quant-int8/quant-int4 "
                f"collectives, not {self.name!r}")
        if self.overlap and self.name not in ("quant-int8", "quant-int4"):
            raise ValueError(
                f"overlapped epilogue only applies to quant-int8/quant-int4 "
                f"collectives, not {self.name!r}")

    # ---- construction -----------------------------------------------------

    @classmethod
    def parse(cls, value) -> "CollectiveSpec":
        """Parse a spec, a string shorthand, or None (-> default psum)."""
        if value is None:
            return cls()
        if isinstance(value, CollectiveSpec):
            return value
        if not isinstance(value, str):
            raise TypeError(
                f"expected CollectiveSpec or string shorthand, "
                f"got {type(value).__name__}")
        name, _, arg = value.partition(":")
        if name == "cast":
            return cls(name="cast", wire_dtype=arg or "bfloat16")
        if name in ("quant-int8", "quant-int4"):
            # quant shorthands: "<name>[:<block>][:fused][:overlap]" —
            # "fused" means the GEMM kernel emits the wire payload,
            # "overlap" the decomposed pipelined ring; trailing flags are
            # accepted in either order, each at most once.
            parts = [p for p in arg.split(":") if p] if arg else []
            flags = set()
            while parts and parts[-1] in ("fused", "overlap"):
                if parts[-1] in flags:
                    raise ValueError(
                        f"collective shorthand {value!r} repeats the "
                        f"':{parts[-1]}' flag")
                flags.add(parts.pop())
            if len(parts) > 1:
                raise ValueError(
                    f"collective shorthand {value!r} has too many ':' "
                    f"arguments (expected "
                    f"'<name>[:<block>][:fused][:overlap]')")
            default_block = 128 if name == "quant-int8" else 32
            return cls(name=name, bits=4 if name == "quant-int4" else None,
                       block_size=int(parts[0]) if parts else default_block,
                       fused="fused" in flags, overlap="overlap" in flags)
        if arg:
            raise ValueError(
                f"collective {name!r} takes no ':' argument (got {value!r})")
        return cls(name=name)

    def shorthand(self) -> str:
        """The string form ``parse`` round-trips (for CLIs / logs)."""
        if self.name == "cast":
            return f"cast:{jnp.dtype(self.wire_dtype).name}"
        if self.name in ("quant-int8", "quant-int4"):
            suffix = (":fused" if self.fused else "") + (
                ":overlap" if self.overlap else "")
            return f"{self.name}:{self.block_size}{suffix}"
        return self.name

    def with_(self, **kw) -> "CollectiveSpec":
        return dataclasses.replace(self, **kw)

    # ---- plan interface ---------------------------------------------------

    def resolve(self, pair_path: Optional[str] = None) -> "CollectiveSpec":
        """A bare spec is a one-entry plan: every pair path resolves to it
        (the uniform lookup call sites use — see ``CollectivePlan``)."""
        return self

    def specs(self) -> tuple["CollectiveSpec", ...]:
        """Distinct specs this plan can resolve to (just itself)."""
        return (self,)

    # ---- analytic cost ----------------------------------------------------

    def bytes_on_wire(self, shape, tp: int) -> float:
        """Analytic per-device ICI bytes to close a row-TP layer whose
        per-rank partial output has ``shape``, over ``tp`` ranks (ring
        cost model, matching ``launch/roofline.py``)."""
        from repro.comm import dispatch
        return dispatch.resolve(self.name).bytes_on_wire(
            tuple(shape), int(tp), self)

    def site_predictions(self, paths, shape, tp: int) -> dict:
        """Per-site analytic prediction table ``{path: {"spec", "bytes"}}``
        for the given pair paths — what ``repro.analysis``'s HLO linter
        checks measured modules against (a bare spec predicts the same
        cost at every site; see ``CollectivePlan.site_predictions``)."""
        return {path: {"spec": self.resolve(path).shorthand(),
                       "bytes": self.resolve(path).bytes_on_wire(shape, tp)}
                for path in paths}


# ---------------------------------------------------------------------------
# per-layer plans
# ---------------------------------------------------------------------------

_PLAN_PREFIX = "per-layer:"


def _normalize_path(path: str) -> str:
    return path.replace("/", ".")


def _match(path: str, pattern: str) -> bool:
    """Glob-match ``pattern`` against a dotted pair path.

    The pattern is tried against the full path AND every dot-suffix, so
    ``"mlp"`` / ``"*.mlp"`` / ``"attn*"`` all hit ``"layers.mlp"`` /
    ``"super.attn.mlp"`` the way a CLI user expects, while a fully
    qualified path (what the autotuner writes) still matches exactly.
    """
    segs = _normalize_path(path).split(".")
    return any(
        fnmatch.fnmatchcase(".".join(segs[i:]), pattern)
        for i in range(len(segs)))


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """Per-layer collective selection, fully specified and frozen.

    An ordered ``(path glob, CollectiveSpec)`` map plus a default:
    ``resolve(pair_path)`` returns the first entry whose glob matches
    the pair's dotted path (e.g. ``"layers.mlp"``,
    ``"layers.moe.experts"``), else ``default``.  Hashable, so it lives
    on ``ExecutionPolicy.collective`` (a jit static argument) exactly
    like a bare ``CollectiveSpec`` — which is the degenerate
    zero-entry plan (see ``CollectiveSpec.resolve``).

    Shorthand (``parse``/``shorthand`` round-trip exactly)::

        per-layer:*.mlp=quant-int8:128,attn*=cast:bfloat16,*=psum

    Entries apply in order; ``*=<spec>`` names the default and must come
    last (anything after a catch-all would be unreachable).
    """

    entries: tuple = ()                       # ((glob, CollectiveSpec), ...)
    default: CollectiveSpec = CollectiveSpec()

    def __post_init__(self):
        ent = []
        for item in self.entries:
            pat, spec = item
            if not isinstance(pat, str) or not pat:
                raise ValueError(
                    f"plan entry pattern must be a non-empty string, "
                    f"got {pat!r}")
            ent.append((pat, CollectiveSpec.parse(spec)))
        object.__setattr__(self, "entries", tuple(ent))
        object.__setattr__(self, "default",
                           CollectiveSpec.parse(self.default))

    # ---- construction -----------------------------------------------------

    @classmethod
    def parse(cls, value) -> "CollectivePlan":
        """Parse a plan, a ``per-layer:`` shorthand, or anything
        ``CollectiveSpec.parse`` accepts (-> one-entry plan)."""
        if isinstance(value, CollectivePlan):
            return value
        if not (isinstance(value, str) and value.startswith(_PLAN_PREFIX)):
            return cls(default=CollectiveSpec.parse(value))
        body = value[len(_PLAN_PREFIX):]
        entries, default, saw_default = [], CollectiveSpec(), False
        for item in body.split(","):
            item = item.strip()
            if not item:
                continue
            if saw_default:
                raise ValueError(
                    f"plan entry {item!r} comes after the catch-all "
                    f"'*=...' and would never match (in {value!r})")
            pat, sep, short = item.partition("=")
            if not sep or not pat:
                raise ValueError(
                    f"plan entry {item!r} is not '<glob>=<spec>' "
                    f"(in {value!r})")
            if pat == "*":
                default, saw_default = CollectiveSpec.parse(short), True
            else:
                entries.append((pat, CollectiveSpec.parse(short)))
        return cls(entries=tuple(entries), default=default)

    def shorthand(self) -> str:
        """The string form ``parse`` round-trips (manifests, CLIs, logs)."""
        parts = [f"{pat}={spec.shorthand()}" for pat, spec in self.entries]
        parts.append(f"*={self.default.shorthand()}")
        return _PLAN_PREFIX + ",".join(parts)

    def with_(self, **kw) -> "CollectivePlan":
        return dataclasses.replace(self, **kw)

    # ---- lookup -----------------------------------------------------------

    def resolve(self, pair_path: Optional[str] = None) -> CollectiveSpec:
        """The spec closing the row-TP epilogue at ``pair_path`` (first
        matching entry, else the default; ``None`` — an anonymous call
        site — always gets the default)."""
        if pair_path is not None:
            for pat, spec in self.entries:
                if _match(pair_path, pat):
                    return spec
        return self.default

    def specs(self) -> tuple[CollectiveSpec, ...]:
        """Distinct specs this plan can resolve to (entry order, default
        last) — what the serve banner and manifest checks enumerate."""
        out = []
        for _, spec in self.entries:
            if spec not in out:
                out.append(spec)
        if self.default not in out:
            out.append(self.default)
        return tuple(out)

    def site_predictions(self, paths, shape, tp: int) -> dict:
        """Per-site analytic prediction table ``{path: {"spec", "bytes"}}``
        — each path resolves its own spec, so this is the plan-level
        ground truth ``repro.analysis`` checks measured HLO and artifact
        manifests against (uniform for a bare ``CollectiveSpec``)."""
        return {path: {"spec": self.resolve(path).shorthand(),
                       "bytes": self.resolve(path).bytes_on_wire(shape, tp)}
                for path in paths}


def parse_collective(value) -> Union[CollectiveSpec, CollectivePlan]:
    """Parse ``ExecutionPolicy.collective``-likes: a spec, a plan, or any
    string shorthand of either (``None`` -> the default psum spec).
    Bare specs stay specs so existing call sites (and policy equality)
    are untouched; only ``per-layer:`` shorthands and explicit plans
    produce a ``CollectivePlan``."""
    if isinstance(value, CollectivePlan) or (
            isinstance(value, str) and value.startswith(_PLAN_PREFIX)):
        return CollectivePlan.parse(value)
    return CollectiveSpec.parse(value)
