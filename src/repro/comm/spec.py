"""CollectiveSpec — the TP epilogue collective as a first-class plan.

The paper's speedup is a *communication* plan decided a priori: TP-Aware
pays only the trailing AllReduce while the Naive Algorithm's AllGather
grows with rank count.  This module names that trailing collective as a
frozen, hashable spec — strategy name, wire dtype, and quantization
parameters — so the whole comm plan travels on the ``ExecutionPolicy``
exactly like the kernel plan does, and compressed collectives
(Hansen-Palmus et al. 2024; Dong et al. 2024) are one registry entry
away instead of a new string branch at every call site.

``CollectiveSpec.parse`` accepts the string shorthands used by configs
and CLIs:

* ``"psum"`` / ``"psum_scatter"`` / ``"none"`` — bit-exact strategies,
* ``"cast"`` or ``"cast:<dtype>"`` — low-bit wire dtype (default bf16),
* ``"quant-int8"`` or ``"quant-int8:<block>"`` — blockwise int8
  quantized all-reduce (block size default 128),
* ``"quant-int4"`` or ``"quant-int4:<block>"`` — blockwise int4: the
  payload is packed 8-nibbles-per-uint32 with the same
  ``quantization.pack_int4`` layout the weights use (block default 32 —
  15 levels need tighter blocks than int8's 255).

Strategy *implementations* live in ``comm/dispatch.py``; the spec only
describes the plan.  ``spec.bytes_on_wire(shape, tp)`` resolves the
strategy's analytic per-device ICI cost so benchmarks and the roofline
can account communication per strategy without compiling anything.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["CollectiveSpec"]

_WIRE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "float16": jnp.float16}


def _canon_wire_dtype(dt):
    """Canonicalize a wire dtype-like (string names allowed; None passes)."""
    if dt is None:
        return None
    if isinstance(dt, str):
        try:
            dt = _WIRE_DTYPES[dt]
        except KeyError:
            raise ValueError(
                f"unknown wire dtype {dt!r}, expected one of "
                f"{sorted(_WIRE_DTYPES)}") from None
    return jax.dtypes.canonicalize_dtype(dt)


@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """One TP epilogue collective, fully specified.

    Frozen + hashable: lives inside ``ExecutionPolicy`` (a jit static
    argument).  ``name`` is a key into the ``comm/dispatch.py`` registry;
    the remaining fields parameterize the strategy:

    * ``wire_dtype`` — the dtype that crosses the ICI (``cast``; also the
      dtype ``bytes_on_wire`` assumes for uncompressed strategies, f32
      when None),
    * ``block_size`` / ``bits`` — blockwise quantization parameters for
      the compressed strategies (``quant-int8``).
    """

    name: str = "psum"
    wire_dtype: Optional[Any] = None
    block_size: int = 128
    bits: Optional[int] = None   # None -> the strategy's payload width

    def __post_init__(self):
        from repro.comm import dispatch  # deferred: dispatch imports spec
        if self.name not in dispatch.strategies():
            raise ValueError(
                f"unknown collective {self.name!r}; registered strategies: "
                f"{list(dispatch.strategies())}")
        if self.name == "cast" and self.wire_dtype is None:
            object.__setattr__(self, "wire_dtype", jnp.bfloat16)
        if self.bits is None:
            object.__setattr__(self, "bits",
                               4 if self.name == "quant-int4" else 8)
        object.__setattr__(self, "wire_dtype",
                           _canon_wire_dtype(self.wire_dtype))
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive, "
                             f"got {self.block_size}")
        if self.bits not in (4, 8):
            raise ValueError(
                f"only 4/8-bit payloads are implemented, got "
                f"bits={self.bits}")
        want_bits = {"quant-int8": 8, "quant-int4": 4}.get(self.name)
        if want_bits is not None and self.bits != want_bits:
            raise ValueError(
                f"{self.name} carries {want_bits}-bit payloads, got "
                f"bits={self.bits}")

    # ---- construction -----------------------------------------------------

    @classmethod
    def parse(cls, value) -> "CollectiveSpec":
        """Parse a spec, a string shorthand, or None (-> default psum)."""
        if value is None:
            return cls()
        if isinstance(value, CollectiveSpec):
            return value
        if not isinstance(value, str):
            raise TypeError(
                f"expected CollectiveSpec or string shorthand, "
                f"got {type(value).__name__}")
        name, _, arg = value.partition(":")
        if name == "cast":
            return cls(name="cast", wire_dtype=arg or "bfloat16")
        if name == "quant-int8":
            return cls(name="quant-int8",
                       block_size=int(arg) if arg else 128)
        if name == "quant-int4":
            return cls(name="quant-int4", bits=4,
                       block_size=int(arg) if arg else 32)
        if arg:
            raise ValueError(
                f"collective {name!r} takes no ':' argument (got {value!r})")
        return cls(name=name)

    def shorthand(self) -> str:
        """The string form ``parse`` round-trips (for CLIs / logs)."""
        if self.name == "cast":
            return f"cast:{jnp.dtype(self.wire_dtype).name}"
        if self.name in ("quant-int8", "quant-int4"):
            return f"{self.name}:{self.block_size}"
        return self.name

    def with_(self, **kw) -> "CollectiveSpec":
        return dataclasses.replace(self, **kw)

    # ---- analytic cost ----------------------------------------------------

    def bytes_on_wire(self, shape, tp: int) -> float:
        """Analytic per-device ICI bytes to close a row-TP layer whose
        per-rank partial output has ``shape``, over ``tp`` ranks (ring
        cost model, matching ``launch/roofline.py``)."""
        from repro.comm import dispatch
        return dispatch.resolve(self.name).bytes_on_wire(
            tuple(shape), int(tp), self)
