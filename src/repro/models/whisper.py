"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder.

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``batch["frames"]`` carries precomputed frame embeddings (B, enc_seq, d).
Sinusoidal positions, LayerNorm, ungated GELU MLPs (quantizable pairs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.common import ParallelContext


#: decoder self-attention consumes precompiled V->O folds (artifact aux
#: plans) — the registry only forwards ``aux`` to modules declaring it.
SUPPORTS_ATTN_VO = True

#: dotted path ``stage_fold_attention`` records the stacked decoder
#: self-attention dicts under.
ATTN_VO_PATH = "dec_layers.attn"

#: folds the plan compiler produces but this runtime deliberately does
#: NOT consume, with the reason — ``repro.analysis`` (MF005) reports
#: these as waived instead of flagging them as dead aux weight.
ATTN_VO_WAIVED = {
    "dec_layers.xattn": (
        "cross-attention K/V is precomputed from raw wv at prefill "
        "(precompute_cross); a folded V would disagree with the cached "
        "values"),
    "enc_layers.attn": (
        "encoder runs once at prefill through GSPMD; the fold targets "
        "the per-token decode path"),
}


def _dec_vo(aux):
    """The stacked (num_layers,) V->O ``PlannedPair`` for the decoder
    self-attention layers, if the artifact carried one."""
    if not aux:
        return None
    return (aux.get("attn_plans") or {}).get(ATTN_VO_PATH)


def _sinusoid(seq: int, d: int):
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_params(cfg, lr):
    lrs = cm.split_rngs(lr, ["attn", "mlp"])
    return {
        "ln1": cm.norm_params(cfg),
        "attn": cm.attention_params(cfg, lrs["attn"]),
        "ln2": cm.norm_params(cfg),
        "mlp": cm.mlp_params(cfg, lrs["mlp"]),
    }


def _dec_layer_params(cfg, lr):
    lrs = cm.split_rngs(lr, ["attn", "xattn", "mlp"])
    return {
        "ln1": cm.norm_params(cfg),
        "attn": cm.attention_params(cfg, lrs["attn"]),
        "lnx": cm.norm_params(cfg),
        "xattn": cm.attention_params(cfg, lrs["xattn"]),
        "ln2": cm.norm_params(cfg),
        "mlp": cm.mlp_params(cfg, lrs["mlp"]),
    }


def init_params(cfg: ModelConfig, rng):
    r = cm.split_rngs(rng, ["embed", "enc", "dec", "norm", "enorm"])
    return {
        "embed": cm.embed_params(cfg, r["embed"]),
        "enc_layers": cm.stack_layer_params(
            lambda lr: _enc_layer_params(cfg, lr), r["enc"],
            cfg.encoder_layers),
        "enc_norm": cm.norm_params(cfg),
        "dec_layers": cm.stack_layer_params(
            lambda lr: _dec_layer_params(cfg, lr), r["dec"], cfg.num_layers),
        "final_norm": cm.norm_params(cfg),
    }


def param_specs(cfg: ModelConfig, params, ctx: ParallelContext):
    axis = ctx.model_axis
    norm = {"scale": P(None, None), "bias": P(None, None)}

    def enc_specs(p):
        return {"ln1": dict(norm), "attn": cm.attention_specs(cfg, axis),
                "ln2": dict(norm), "mlp": cm.mlp_specs(cfg, p["mlp"], axis)}

    def dec_specs(p):
        return {"ln1": dict(norm), "attn": cm.attention_specs(cfg, axis),
                "lnx": dict(norm), "xattn": cm.attention_specs(cfg, axis),
                "ln2": dict(norm), "mlp": cm.mlp_specs(cfg, p["mlp"], axis)}

    fnorm = {"scale": P(None), "bias": P(None)}
    return {
        "embed": cm.embed_specs(cfg, axis, ctx.axis_size(axis)),
        "enc_layers": enc_specs(params["enc_layers"]),
        "enc_norm": dict(fnorm),
        "dec_layers": dec_specs(params["dec_layers"]),
        "final_norm": dict(fnorm),
    }


def encode(cfg: ModelConfig, params, frames, ctx: ParallelContext):
    """frames: (B, enc_seq, d) stub embeddings -> encoder states."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = ctx.shard(x, ctx.batch_spec, None, None)

    def body(x, lp, _):
        h = cm.attention_forward(cfg, lp["attn"],
                                 cm.apply_norm(cfg, lp["ln1"], x), ctx,
                                 causal=False)
        x = x + h
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path="enc_layers.mlp")
        return x + h

    x = cm.scan_layers(body, x, params["enc_layers"], ctx)
    return cm.apply_norm(cfg, params["enc_norm"], x)


def _dec_layer(cfg, ctx):
    def body(x, lp, enc):
        h = cm.attention_forward(cfg, lp["attn"],
                                 cm.apply_norm(cfg, lp["ln1"], x), ctx,
                                 vo=lp.get("attn_vo"))
        x = x + h
        h = cm.attention_forward(cfg, lp["xattn"],
                                 cm.apply_norm(cfg, lp["lnx"], x), ctx,
                                 kv_x=enc, causal=False)
        x = x + h
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path="dec_layers.mlp")
        return x + h
    return body


def forward(cfg: ModelConfig, params, batch, ctx: ParallelContext, *,
            window=None, aux=None):
    """batch: {"tokens": (B, S), "frames": (B, enc_seq, d)} -> logits."""
    enc = encode(cfg, params, batch["frames"], ctx)
    tok = batch["tokens"]
    x = cm.embed_tokens(cfg, params["embed"], tok, ctx)
    x = x + _sinusoid(tok.shape[1], cfg.d_model).astype(x.dtype)
    dec = params["dec_layers"]
    vo = _dec_vo(aux)
    if vo is not None:
        # rides the decoder scan next to the layer params; the body
        # picks it up as lp["attn_vo"]
        dec = dict(dec, attn_vo=vo)
    x = cm.scan_layers(_dec_layer(cfg, ctx), x, dec, ctx,
                       extra=enc)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    return cm.lm_head(cfg, params["embed"], x, ctx)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, window=None,
               dtype=jnp.bfloat16):
    """Decoder self-attn cache + precomputed cross K/V per layer."""
    l = cfg.num_layers
    kvh, _, _ = cm.head_grid(cfg)
    hd = cfg.head_dim
    return {
        "self": cm.init_kv_cache(cfg, l, batch, seq_len, window=window,
                                 dtype=dtype),
        "cross_k": jnp.zeros((l, batch, cfg.encoder_seq, kvh, hd), dtype),
        "cross_v": jnp.zeros((l, batch, cfg.encoder_seq, kvh, hd), dtype),
    }


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, *, bits=None, dtype=jnp.bfloat16):
    """Paged decoder self-attn pool; cross K/V stays dense (fixed
    encoder_seq per slot, written once at prefill — nothing to page)."""
    l = cfg.num_layers
    kvh, _, _ = cm.head_grid(cfg)
    hd = cfg.head_dim
    return {
        "self": cm.init_paged_kv_cache(cfg, l, n_pages, page_size,
                                       bits=bits, dtype=dtype),
        "cross_k": jnp.zeros((l, batch, cfg.encoder_seq, kvh, hd), dtype),
        "cross_v": jnp.zeros((l, batch, cfg.encoder_seq, kvh, hd), dtype),
    }


def cache_specs(cfg: ModelConfig, ctx: ParallelContext):
    xspec = P(None, ctx.batch_spec, None, None, None)
    return {"self": cm.kv_cache_specs(cfg, ctx),
            "cross_k": xspec, "cross_v": xspec}


def precompute_cross(cfg: ModelConfig, params, enc, ctx: ParallelContext):
    """Fill cross K/V cache entries from encoder states (prefill)."""
    b, t, _ = enc.shape
    kvh, _, _ = cm.head_grid(cfg)
    hd = cfg.head_dim

    def per_layer(lp):
        k = (enc @ lp["xattn"]["wk"]).reshape(b, t, kvh, hd)
        v = (enc @ lp["xattn"]["wv"]).reshape(b, t, kvh, hd)
        return k, v

    ks, vs = jax.vmap(per_layer, in_axes=(0,))(params["dec_layers"])
    return ks, vs


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                ctx: ParallelContext, *, window=None, pages=None, aux=None):
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], ctx)
    d = cfg.d_model
    pos_emb = _sinusoid(cfg.max_target_positions or 448, d)
    if jnp.ndim(pos):
        # per-slot clocks: gather each slot's own position embedding
        idx = jnp.minimum(jnp.asarray(pos, jnp.int32), pos_emb.shape[0] - 1)
        x = x + pos_emb[idx][:, None].astype(x.dtype)
    else:
        x = x + jax.lax.dynamic_slice(pos_emb, (jnp.minimum(
            pos, pos_emb.shape[0] - 1), 0), (1, d)).astype(x.dtype)[None]

    def body(x, xs):
        lp, (lc, xk, xv) = xs
        h, nc = cm.attention_decode(cfg, lp["attn"],
                                    cm.apply_norm(cfg, lp["ln1"], x),
                                    lc, pos, ctx, window=window, pages=pages,
                                    vo=lp.get("attn_vo"))
        x = x + h
        # cross-attn against precomputed encoder K/V
        xa = lp["xattn"]
        b = x.shape[0]
        q = (cm.apply_norm(cfg, lp["lnx"], x) @ xa["wq"]).reshape(
            b, 1, cm.head_grid(cfg)[2], cfg.head_dim)
        out = cm._sdpa(cfg, ctx, q, xk.astype(x.dtype), xv.astype(x.dtype),
                       None)
        x = x + out @ xa["wo"]
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path="dec_layers.mlp")
        return (x + h).astype(carry_dtype), nc

    carry_dtype = x.dtype
    dec = params["dec_layers"]
    vo = _dec_vo(aux)
    if vo is not None:
        dec = dict(dec, attn_vo=vo)
    x, ncache = jax.lax.scan(
        body, x, (dec,
                  (cache["self"], cache["cross_k"], cache["cross_v"])))
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.lm_head(cfg, params["embed"], x, ctx)
    return logits[:, 0], {"self": ncache, "cross_k": cache["cross_k"],
                          "cross_v": cache["cross_v"]}
