"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free, data-dependent decay.

Each layer = time-mix (multi-head linear-attention-style recurrence with
per-channel data-dependent decay w_t and bonus u) + channel-mix.

* time-mix state per head: S (dk, dv);  S_t = diag(w_t) S_{t-1} + k_t v_t^T;
  out_t = r_t (S_{t-1} + diag(u) k_t v_t^T)  — evaluated by lax.scan over
  sequence for training/prefill and a single step for decode (O(1) state ->
  long_500k runs natively).
* data-dependent token-shift (ddlerp) with the paper's low-rank (rank 32)
  adapters, and the decay LoRA w_t = exp(-exp(w0 + tanh(x W_a) W_b)).
* channel-mix: r-gated squared-ReLU FFN; its K->V projection pair is a
  column-TP -> row-TP pair, so the paper's TP-aware fold applies to it
  (DESIGN.md §5) — the time-mix recurrence itself is out of scope.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.common import ParallelContext

LORA_RANK = 32
MIX_NAMES = ("r", "k", "v", "g", "w")  # ddlerp targets


def time_mix_params(cfg: ModelConfig, rng):
    d = cfg.d_model
    r = cm.split_rngs(rng, ["r", "k", "v", "g", "o", "maa1", "maa2",
                            "w1", "w2"])
    return {
        "mu_x": jnp.full((d,), 0.5),
        "mu": jnp.stack([jnp.full((d,), 0.5)] * len(MIX_NAMES)),  # (5, d)
        "maa_w1": cm.dense_init(r["maa1"], (d, len(MIX_NAMES) * LORA_RANK)),
        "maa_w2": cm.dense_init(r["maa2"], (len(MIX_NAMES), LORA_RANK, d)),
        "w_r": cm.dense_init(r["r"], (d, d)),
        "w_k": cm.dense_init(r["k"], (d, d)),
        "w_v": cm.dense_init(r["v"], (d, d)),
        "w_g": cm.dense_init(r["g"], (d, d)),
        "w_o": cm.dense_init(r["o"], (d, d)),
        "decay_base": jnp.linspace(-6.0, -1.0, d),     # w0
        "decay_w1": cm.dense_init(r["w1"], (d, LORA_RANK)),
        "decay_w2": cm.dense_init(r["w2"], (LORA_RANK, d)),
        "bonus_u": jnp.linspace(-0.5, 0.5, d),
        "ln_scale": jnp.ones(d),
    }


def time_mix_specs(cfg: ModelConfig, axis):
    return {
        "mu_x": P(None, None), "mu": P(None, None, None),
        "maa_w1": P(None, None, None), "maa_w2": P(None, None, None, None),
        "w_r": P(None, None, axis), "w_k": P(None, None, axis),
        "w_v": P(None, None, axis), "w_g": P(None, None, axis),
        "w_o": P(None, axis, None),
        "decay_base": P(None, None), "decay_w1": P(None, None, None),
        "decay_w2": P(None, None, None), "bonus_u": P(None, None),
        "ln_scale": P(None, None),
    }


def _ddlerp(p, x, xx):
    """Data-dependent token-shift interpolation -> dict of mixed inputs."""
    base = x + (xx - x) * p["mu_x"]
    lora = jnp.tanh(base @ p["maa_w1"])           # (..., 5*R)
    lora = lora.reshape(*lora.shape[:-1], len(MIX_NAMES), LORA_RANK)
    delta = jnp.einsum("...nr,nrd->...nd", lora, p["maa_w2"])  # (..., 5, d)
    out = {}
    for i, name in enumerate(MIX_NAMES):
        mix = p["mu"][i] + delta[..., i, :]
        out[name] = x + (xx - x) * mix
    return out


def _wkv_step(s, rkvwu):
    """One recurrence step per head.  s: (H, dk, dv)."""
    r, k, v, w, u = rkvwu                     # r/k/w: (H, dk); v: (H, dv)
    kv = k[:, :, None] * v[:, None, :]        # (H, dk, dv)
    out = jnp.einsum("hk,hkv->hv", r, s + u[:, :, None] * kv)
    s_new = w[:, :, None] * s + kv
    return s_new, out


def time_mix_forward(cfg: ModelConfig, p, x, ctx: ParallelContext,
                     state=None):
    """x: (B, S, d).  state: {"shift": (B, d), "wkv": (B, H, dk, dv)}."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd

    if state is not None:
        prev = state["shift"]
    else:
        prev = jnp.zeros((b, d), x.dtype)
    xx = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)  # shifted
    m = _ddlerp(p, x, xx)

    r = (m["r"] @ p["w_r"]).reshape(b, s, h, hd)
    k = (m["k"] @ p["w_k"]).reshape(b, s, h, hd)
    v = (m["v"] @ p["w_v"]).reshape(b, s, h, hd)
    g = jax.nn.silu(m["g"] @ p["w_g"])
    decay = p["decay_base"] + jnp.tanh(m["w"] @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32))).reshape(b, s, h, hd)
    u = p["bonus_u"].reshape(h, hd)

    s0 = (state["wkv"] if state is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))

    def per_batch(s0_b, rb, kb, vb, wb):
        def step(carry, t):
            return _wkv_step(carry, (rb[t].astype(jnp.float32),
                                     kb[t].astype(jnp.float32),
                                     vb[t].astype(jnp.float32),
                                     wb[t], u.astype(jnp.float32)))
        s_fin, outs = jax.lax.scan(step, s0_b, jnp.arange(s))
        return s_fin, outs                    # outs: (S, H, dv)

    s_fin, out = jax.vmap(per_batch)(s0, r, k, v, w)
    out = out.reshape(b, s, d)
    # per-head group norm then gate
    out = out.reshape(b, s, h, hd)
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(b, s, d) * p["ln_scale"]
    out = (out.astype(x.dtype) * g)
    out = ctx.shard(out, ctx.batch_spec, None, None)
    y = out @ p["w_o"]
    new_state = {"shift": x[:, -1], "wkv": s_fin}
    return ctx.shard(y, ctx.batch_spec, None, None), new_state


def channel_mix_params(cfg: ModelConfig, rng):
    d, ff = cfg.d_model, cfg.d_ff
    r = cm.split_rngs(rng, ["r", "pair"])
    return {
        "mu_k": jnp.full((d,), 0.5),
        "mu_r": jnp.full((d,), 0.5),
        "w_r": cm.dense_init(r["r"], (d, d)),
        "pair": cm.mlp_params(cfg, r["pair"], d_ff=ff),
    }


def channel_mix_specs(cfg: ModelConfig, p, axis):
    return {
        "mu_k": P(None, None), "mu_r": P(None, None),
        "w_r": P(None, None, None),
        "pair": cm.mlp_specs(cfg, p["pair"], axis),
    }


def channel_mix_forward(cfg: ModelConfig, p, x, ctx: ParallelContext,
                        state=None):
    b, s, d = x.shape
    prev = state if state is not None else jnp.zeros((b, d), x.dtype)
    xx = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    xk = x + (xx - x) * p["mu_k"]
    xr = x + (xx - x) * p["mu_r"]
    rgate = jax.nn.sigmoid(xr @ p["w_r"])
    # K->V pair: squared-relu "activation" between up and down — this is the
    # column-TP -> row-TP pair the paper's fold applies to.
    v = cm.mlp_forward(cfg, p["pair"], xk, ctx, activation="relu2",
                       path="layers.cm.pair")
    return rgate * v, x[:, -1]


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng):
    r = cm.split_rngs(rng, ["embed", "layers", "norm"])

    def make_layer(lr):
        lrs = cm.split_rngs(lr, ["tm", "cm"])
        return {
            "ln1": cm.norm_params(cfg),
            "tm": time_mix_params(cfg, lrs["tm"]),
            "ln2": cm.norm_params(cfg),
            "cm": channel_mix_params(cfg, lrs["cm"]),
        }

    return {
        "embed": cm.embed_params(cfg, r["embed"]),
        "layers": cm.stack_layer_params(make_layer, r["layers"],
                                        cfg.num_layers),
        "final_norm": cm.norm_params(cfg),
    }


def param_specs(cfg: ModelConfig, params, ctx: ParallelContext):
    axis = ctx.model_axis
    norm = {"scale": P(None, None)}
    return {
        "embed": cm.embed_specs(cfg, axis, ctx.axis_size(axis)),
        "layers": {
            "ln1": dict(norm),
            "tm": time_mix_specs(cfg, axis),
            "ln2": dict(norm),
            "cm": channel_mix_specs(cfg, params["layers"]["cm"], axis),
        },
        "final_norm": {"scale": P(None)},
    }


def forward(cfg: ModelConfig, params, batch, ctx: ParallelContext, *,
            window=None):
    x = cm.embed_tokens(cfg, params["embed"], batch["tokens"], ctx)

    def body(x, lp, _):
        h, _s = time_mix_forward(cfg, lp["tm"],
                                 cm.apply_norm(cfg, lp["ln1"], x), ctx)
        x = x + h
        h, _s = channel_mix_forward(cfg, lp["cm"],
                                    cm.apply_norm(cfg, lp["ln2"], x), ctx)
        return x + h

    x = cm.scan_layers(body, x, params["layers"], ctx)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    return cm.lm_head(cfg, params["embed"], x, ctx)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, window=None,
               dtype=jnp.bfloat16):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    l = cfg.num_layers
    return {
        "tm_shift": jnp.zeros((l, batch, d), dtype),
        "wkv": jnp.zeros((l, batch, h, hd, hd), jnp.float32),
        "cm_shift": jnp.zeros((l, batch, d), dtype),
    }


def cache_specs(cfg: ModelConfig, ctx: ParallelContext):
    return {
        "tm_shift": P(None, ctx.batch_spec, None),
        # (L, B, H, dk, dv): H (40) doesn't divide a 16-way axis; dk (64)
        # does — shard the state over dk instead.
        "wkv": P(None, ctx.batch_spec, None, ctx.model_axis, None),
        "cm_shift": P(None, ctx.batch_spec, None),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                ctx: ParallelContext, *, window=None, pages=None):
    # ``pages`` accepted for interface uniformity and ignored: rwkv6's
    # entire decode state is O(1) per slot (shift rows + wkv matrix) —
    # there is no KV sequence to page.
    del pages
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], ctx)

    def body(x, xs):
        lp, (ts, wkv, cs) = xs
        h, ns_tm = time_mix_forward(
            cfg, lp["tm"], cm.apply_norm(cfg, lp["ln1"], x), ctx,
            state={"shift": ts, "wkv": wkv})
        x = x + h
        h, ns_cm = channel_mix_forward(
            cfg, lp["cm"], cm.apply_norm(cfg, lp["ln2"], x), ctx, state=cs)
        x = x + h
        return x.astype(carry_dtype), (ns_tm["shift"], ns_tm["wkv"], ns_cm)

    carry_dtype = x.dtype
    x, (nts, nwkv, ncs) = jax.lax.scan(
        body, x, (params["layers"],
                  (cache["tm_shift"], cache["wkv"], cache["cm_shift"])))
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.lm_head(cfg, params["embed"], x, ctx)
    return logits[:, 0], {"tm_shift": nts, "wkv": nwkv, "cm_shift": ncs}
