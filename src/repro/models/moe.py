"""Mixture-of-Experts decoder (qwen3-moe, arctic).

Token-choice top-k routing with capacity-based gather/scatter dispatch:
the dispatch is expressed with gathers/scatters (memory ops), NOT one-hot
einsums, so the dry-run's cost_analysis reports honest FLOPs (a one-hot
dispatch einsum would claim T*E*C*d fake MACs).

Experts are quantized PlannedPairs stacked over E (and L); the paper's
act_order locality applies per-expert.  Experts are sharded over the
``data`` axis (EP) and the expert FFN runs per-shard; see DESIGN.md §5 for
why intra-expert TP-aware folding is a no-op under pure EP.

arctic: ``dense_residual=True`` adds a parallel dense (TP-sharded,
TP-aware-folded) MLP to every layer — that one exercises the paper's
technique directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import CollectiveSpec, dispatch as comm_dispatch
from repro.core import compat, schemes
from repro.core.policy import ExecutionPolicy

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.common import ParallelContext

#: dotted pair paths matching the plan compiler's manifest entries — the
#: keys a per-layer ``CollectivePlan`` addresses these epilogues by
EXPERTS_PATH = "layers.moe.experts"
DENSE_MLP_PATH = "layers.moe.dense_mlp"


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.num_experts)
    return max(4, min(tokens, c))


def moe_block_params(cfg: ModelConfig, rng):
    r = cm.split_rngs(rng, ["router", "experts", "dense"])
    p = {
        "router": cm.dense_init(r["router"], (cfg.d_model, cfg.num_experts)),
        "experts": cm.stack_layer_params(
            lambda er: cm.mlp_params(cfg, er, d_ff=cfg.moe_dff),
            r["experts"], cfg.num_experts),
    }
    if cfg.dense_residual:
        p["dense_mlp"] = cm.mlp_params(cfg, r["dense"], d_ff=cfg.d_ff)
    return p


def moe_block_specs(cfg: ModelConfig, p, ctx: ParallelContext):
    # experts: E over the data axis (EP) AND the expert FFN's inner dims
    # over the model axis (TP within expert) — both are needed for the
    # big-MoE (arctic/qwen3-moe) weights to fit per-chip at scale.
    ep = ctx.ep_axis
    specs = {
        "router": P(None, None, None),
        "experts": cm.mlp_specs(cfg, p["experts"], ctx.model_axis,
                                lead=(None, ep)),
    }
    if cfg.dense_residual:
        specs["dense_mlp"] = cm.mlp_specs(cfg, p["dense_mlp"],
                                          ctx.model_axis)
    return specs


def _dispatch_local(cfg: ModelConfig, xt: jax.Array, router: jax.Array,
                    cap: int):
    """Token-choice top-k dispatch for a local token set.

    Returns (buf (E, cap, d), combine_fn(expert_out (E, cap, d)) -> (T, d)).
    """
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.top_k
    scores = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    flat_tok = jnp.repeat(jnp.arange(t), k)

    buf = jnp.zeros((e, cap, d), dtype=xt.dtype)
    buf = buf.at[flat_e, jnp.where(keep, flat_pos, cap - 1)].add(
        jnp.where(keep[:, None], xt[flat_tok], 0).astype(xt.dtype),
        mode="drop")

    def combine(out):
        slots = out[flat_e, jnp.where(keep, flat_pos, 0)]
        slots = slots * (gate.reshape(-1)[:, None]
                         * keep[:, None]).astype(out.dtype)
        return jnp.zeros((t, d), out.dtype).at[flat_tok].add(slots)

    return buf, combine, (probs, idx)


def _expert_ffn_local(cfg: ModelConfig, experts, xs, tp_axis: str,
                      policy: ExecutionPolicy):
    """Per-rank expert FFN: ``xs (E_l, C, d)`` through this rank's expert
    shards (inner dims tp-sharded over ``tp_axis``); psum over tp."""
    from repro.core.reorder import PlannedPair

    if isinstance(experts, PlannedPair):
        # within-expert TP resolves its own spec from the deployment plan
        # (path "layers.moe.experts"), like every other epilogue — but the
        # EP combine needs every rank's COMPLETE expert output, so
        # strategies that scatter the result or skip the reduction fall
        # back to full-precision psum (compressed full-output strategies
        # like quant-int8 are fine: they still return the whole tensor).
        # The vmapped per-expert GEMMs stay on the jnp kernel — Pallas
        # under vmap-of-shard_map is not a supported lowering.
        spec = policy.collective.resolve(EXPERTS_PATH)
        if spec.name == "none" or comm_dispatch.scatters_output(spec):
            spec = CollectiveSpec(name="psum")
        pol = policy.with_(collective=spec, backend="jnp")
        fn = functools.partial(
            schemes._pair_local_forward, axis=tp_axis,
            activation=cfg.activation, policy=pol)
        return jax.vmap(fn)(xs, experts).astype(xs.dtype)

    act = schemes.ACTIVATIONS[cfg.activation]
    h = jnp.einsum("ecd,edf->ecf", xs, experts["w_up"].astype(xs.dtype))
    if "w_gate" in experts:
        h = act(jnp.einsum("ecd,edf->ecf", xs,
                           experts["w_gate"].astype(xs.dtype))) * h
    else:
        h = act(h)
    y = jnp.einsum("ecf,efd->ecd", h, experts["w_down"].astype(xs.dtype))
    return comm_dispatch.raw_psum(y, tp_axis)


def moe_forward_ep(cfg: ModelConfig, p, x, ctx: ParallelContext):
    """Explicit expert-parallel MoE layer (GShard-style) under shard_map.

    Why this exists: GSPMD cannot shard the scatter/gather dispatch of the
    auto-partitioned path — measured on qwen3-moe it *replicates* the
    expert GEMMs on all 256 chips (364x the ideal per-device FLOPs; see
    EXPERIMENTS.md §Perf).  Here the parallelism is explicit:

      tokens local per data rank -> local top-k dispatch into per-expert
      capacity buffers -> all_to_all over the data axis (tokens travel to
      the rank owning their expert) -> expert FFN with the within-expert
      dims tp-sharded over the model axis (+psum) -> all_to_all back ->
      local gate-weighted combine.
    """
    mesh = ctx.mesh
    dp = ctx.ep_axis
    tp = ctx.model_axis
    b, s, d = x.shape
    e = cfg.num_experts
    dsize = ctx.axis_size(dp)
    batch_sharded = bool(ctx.batch_axes) and b % dsize == 0

    x_spec = P(ctx.batch_spec if batch_sharded else None, None, None)
    especs = cm.mlp_specs(cfg, p["experts"], tp, lead=(dp,))
    in_specs = (x_spec, P(None, None), especs)

    t_local = (b // dsize if batch_sharded else b) * s
    cap = _capacity(cfg, t_local)

    pol = ctx.execution_policy

    def body(x_l, router, experts_l):
        bl, sl, _ = x_l.shape
        xt = x_l.reshape(bl * sl, d)
        buf, combine, _aux = _dispatch_local(cfg, xt, router, cap)
        # (E, cap, d) -> (E/D, D*cap, d): tokens travel to expert owners
        buf = comm_dispatch.all_to_all(buf, dp, split_axis=0,
                                       concat_axis=1)
        out = _expert_ffn_local(cfg, experts_l, buf, tp, pol)
        # (E/D, D*cap, d) -> (E, cap, d): results travel home
        out = comm_dispatch.all_to_all(out, dp, split_axis=1,
                                       concat_axis=0)
        return combine(out).reshape(bl, sl, d)

    y = compat.shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=x_spec,
    )(x, p["router"], p["experts"])

    if cfg.dense_residual:
        y = y + cm.mlp_forward(cfg, p["dense_mlp"], x, ctx,
                               path=DENSE_MLP_PATH)
    return y


def moe_forward(cfg: ModelConfig, p, x, ctx: ParallelContext,
                return_aux: bool = False):
    """x: (B, S, d) -> (B, S, d) [, aux load-balance loss]."""
    if (ctx.mesh is not None and ctx.shard_map_mlp and not return_aux
            and ctx.ep_axis is not None
            and cfg.num_experts % ctx.axis_size(ctx.ep_axis) == 0):
        return moe_forward_ep(cfg, p, x, ctx)
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.num_experts, cfg.top_k
    cap = _capacity(cfg, t)

    scores = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)                       # (T, E)
    gate, idx = jax.lax.top_k(probs, k)                           # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)           # renorm

    # --- dispatch: position of each (token, slot) within its expert -------
    flat_e = idx.reshape(-1)                                      # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                          # (T*k, E)
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    flat_tok = jnp.repeat(jnp.arange(t), k)

    buf = jnp.zeros((e, cap, d), dtype=x.dtype)
    buf = buf.at[flat_e, jnp.where(keep, flat_pos, cap - 1)].add(
        jnp.where(keep[:, None], xt[flat_tok], 0), mode="drop")
    buf = ctx.shard(buf, ctx.ep_axis, None, None)

    # --- expert FFN (vmapped over E; quantized pairs keep act_order) ------
    def one_expert(ep, ex):
        return cm.mlp_forward(cfg, ep, ex[None], cm.REPLICATED)[0]

    out = jax.vmap(one_expert)(p["experts"], buf)                 # (E, C, d)
    out = ctx.shard(out, ctx.ep_axis, None, None)

    # --- combine -----------------------------------------------------------
    slots = out[flat_e, jnp.where(keep, flat_pos, 0)]             # (T*k, d)
    slots = slots * (gate.reshape(-1)[:, None] * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((t, d), dtype=x.dtype).at[flat_tok].add(slots)
    y = y.reshape(b, s, d)

    if cfg.dense_residual:
        y = y + cm.mlp_forward(cfg, p["dense_mlp"], x, ctx,
                               path=DENSE_MLP_PATH)

    if return_aux:
        # Switch-style load-balance loss: E * sum_e f_e * P_e
        frac = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1))
        pmean = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac * pmean)
        return y, aux
    return y


# ---------------------------------------------------------------------------
# full model: dense transformer skeleton with MoE blocks as the MLP
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng):
    r = cm.split_rngs(rng, ["embed", "layers", "norm"])

    def make_layer(lr):
        lrs = cm.split_rngs(lr, ["attn", "moe"])
        return {
            "ln1": cm.norm_params(cfg),
            "attn": cm.attention_params(cfg, lrs["attn"]),
            "ln2": cm.norm_params(cfg),
            "moe": moe_block_params(cfg, lrs["moe"]),
        }

    return {
        "embed": cm.embed_params(cfg, r["embed"]),
        "layers": cm.stack_layer_params(make_layer, r["layers"],
                                        cfg.num_layers),
        "final_norm": cm.norm_params(cfg),
    }


def param_specs(cfg: ModelConfig, params, ctx: ParallelContext):
    axis = ctx.model_axis
    norm = {"scale": P(None, None)} if cfg.norm_type == "rms" else \
        {"scale": P(None, None), "bias": P(None, None)}
    return {
        "embed": cm.embed_specs(cfg, axis, ctx.axis_size(axis)),
        "layers": {
            "ln1": dict(norm),
            "attn": cm.attention_specs(cfg, axis),
            "ln2": dict(norm),
            "moe": moe_block_specs(cfg, params["layers"]["moe"], ctx),
        },
        "final_norm": {k: P(None) for k in
                       (("scale", "bias") if cfg.norm_type == "layernorm"
                        else ("scale",))},
    }


def _layer(cfg, ctx, window, aux_acc=False):
    def body(x, lp, _):
        h = cm.attention_forward(cfg, lp["attn"],
                                 cm.apply_norm(cfg, lp["ln1"], x), ctx,
                                 window=window)
        x = x + h
        h = moe_forward(cfg, lp["moe"], cm.apply_norm(cfg, lp["ln2"], x), ctx)
        return x + h
    return body


def forward(cfg: ModelConfig, params, batch, ctx: ParallelContext, *,
            window=None):
    x = cm.embed_tokens(cfg, params["embed"], batch["tokens"], ctx)
    x = cm.scan_layers(_layer(cfg, ctx, window), x, params["layers"], ctx)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    return cm.lm_head(cfg, params["embed"], x, ctx)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, window=None,
               dtype=jnp.bfloat16):
    return cm.init_kv_cache(cfg, cfg.num_layers, batch, seq_len,
                            window=window, dtype=dtype)


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, *, bits=None, dtype=jnp.bfloat16):
    del batch  # pure pool: per-slot state lives in the page table
    return cm.init_paged_kv_cache(cfg, cfg.num_layers, n_pages, page_size,
                                  bits=bits, dtype=dtype)


def cache_specs(cfg: ModelConfig, ctx: ParallelContext):
    return cm.kv_cache_specs(cfg, ctx)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                ctx: ParallelContext, *, window=None, pages=None):
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], ctx)

    def body(x, lp, lc, _):
        h, nc = cm.attention_decode(cfg, lp["attn"],
                                    cm.apply_norm(cfg, lp["ln1"], x),
                                    lc, pos, ctx, window=window, pages=pages)
        x = x + h
        h = moe_forward(cfg, lp["moe"], cm.apply_norm(cfg, lp["ln2"], x), ctx)
        return x + h, nc

    x, new_cache = cm.scan_layers_cache(body, x, params["layers"], cache, ctx)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.lm_head(cfg, params["embed"], x, ctx)
    return logits[:, 0], new_cache
