"""Llama-3.2-Vision backbone: decoder with gated cross-attention image
layers every ``cross_attn_every`` layers (assignment: 100L = 80 self + 20
cross).  The ViT/SigLIP vision encoder + projector is a STUB:
``batch["patches"]`` carries precomputed patch embeddings
(B, vision_tokens, d_model).

Structure: scan over ``n_super = L / cross_attn_every`` superblocks, each =
(cross_attn_every - 1) self layers (inner scan) + 1 gated cross layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.common import ParallelContext


#: decoder self-attention consumes precompiled V->O folds (artifact aux
#: plans) — the registry only forwards ``aux`` to modules declaring it.
#: Cross-attention layers are NOT folded into the runtime path: their
#: K/V is patch-derived and precomputed (``precompute_cross``), so the
#: fold's within-head-block permutation has nothing to commute with.
SUPPORTS_ATTN_VO = True

#: dotted path ``stage_fold_attention`` records the stacked
#: (n_super, n_self) decoder self-attention dicts under.
ATTN_VO_PATH = "super.self.attn"

#: folds the plan compiler produces but this runtime deliberately does
#: NOT consume, with the reason — ``repro.analysis`` (MF005) reports
#: these as waived instead of flagging them as dead aux weight.
ATTN_VO_WAIVED = {
    "super.cross.xattn": (
        "cross-attention K/V is precomputed from raw wv at prefill "
        "(precompute_cross); a folded V would disagree with the cached "
        "values"),
}


def _self_vo(aux):
    """The stacked (ns, nself) V->O ``PlannedPair`` for the decoder self
    layers, if the artifact carried one (scanned alongside the params:
    the outer scan peels ns, the inner scan peels nself)."""
    if not aux:
        return None
    return (aux.get("attn_plans") or {}).get(ATTN_VO_PATH)


def _n_super(cfg: ModelConfig):
    assert cfg.num_layers % cfg.cross_attn_every == 0
    return cfg.num_layers // cfg.cross_attn_every, cfg.cross_attn_every - 1


def _self_layer_params(cfg, lr):
    lrs = cm.split_rngs(lr, ["attn", "mlp"])
    return {
        "ln1": cm.norm_params(cfg),
        "attn": cm.attention_params(cfg, lrs["attn"]),
        "ln2": cm.norm_params(cfg),
        "mlp": cm.mlp_params(cfg, lrs["mlp"]),
    }


def _cross_layer_params(cfg, lr):
    lrs = cm.split_rngs(lr, ["xattn", "mlp"])
    return {
        "ln1": cm.norm_params(cfg),
        "xattn": cm.attention_params(cfg, lrs["xattn"]),
        "ln2": cm.norm_params(cfg),
        "mlp": cm.mlp_params(cfg, lrs["mlp"]),
        "gate_attn": jnp.zeros(()),
        "gate_mlp": jnp.zeros(()),
    }


def init_params(cfg: ModelConfig, rng):
    ns, nself = _n_super(cfg)
    r = cm.split_rngs(rng, ["embed", "super", "norm"])

    def make_super(lr):
        lrs = cm.split_rngs(lr, ["self", "cross"])
        return {
            "self": cm.stack_layer_params(
                lambda slr: _self_layer_params(cfg, slr), lrs["self"], nself),
            "cross": _cross_layer_params(cfg, lrs["cross"]),
        }

    return {
        "embed": cm.embed_params(cfg, r["embed"]),
        "super": cm.stack_layer_params(make_super, r["super"], ns),
        "final_norm": cm.norm_params(cfg),
    }


def param_specs(cfg: ModelConfig, params, ctx: ParallelContext):
    axis = ctx.model_axis
    norm2 = {"scale": P(None, None, None)}  # (ns, nself, d)
    norm1 = {"scale": P(None, None)}

    def attn_specs(stack_dims):
        base = cm.attention_specs(cfg, axis, stacked=False)
        return jax.tree.map(
            lambda s: P(*((None,) * stack_dims), *s), base,
            is_leaf=lambda x: isinstance(x, P))

    sup = params["super"]
    self_mlp = jax.tree.map(
        lambda s: P(None, *s) if isinstance(s, P) else s,
        cm.mlp_specs(cfg, sup["self"]["mlp"], axis),
        is_leaf=lambda x: isinstance(x, P))
    return {
        "embed": cm.embed_specs(cfg, axis, ctx.axis_size(axis)),
        "super": {
            "self": {"ln1": dict(norm2), "attn": attn_specs(2),
                     "ln2": dict(norm2), "mlp": self_mlp},
            "cross": {"ln1": dict(norm1), "xattn": attn_specs(1),
                      "ln2": dict(norm1),
                      "mlp": cm.mlp_specs(cfg, sup["cross"]["mlp"], axis),
                      "gate_attn": P(None), "gate_mlp": P(None)},
        },
        "final_norm": {"scale": P(None)},
    }


def _cross_layer_fwd(cfg, ctx):
    def body(x, lp, patches):
        h = cm.attention_forward(cfg, lp["xattn"],
                                 cm.apply_norm(cfg, lp["ln1"], x), ctx,
                                 kv_x=patches, causal=False)
        x = x + jnp.tanh(lp["gate_attn"]) * h
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path="super.cross.mlp")
        return x + jnp.tanh(lp["gate_mlp"]) * h
    return body


def forward(cfg: ModelConfig, params, batch, ctx: ParallelContext, *,
            window=None, aux=None):
    """batch: {"tokens": (B, S), "patches": (B, vision_tokens, d)}."""
    patches = batch["patches"]
    x = cm.embed_tokens(cfg, params["embed"], batch["tokens"], ctx)
    self_fwd = tfm._layer(cfg, ctx, window,
                          mlp_path="super.self.mlp")
    cross_fwd = _cross_layer_fwd(cfg, ctx)

    def super_body(x, sp, _):
        x = cm.scan_layers(self_fwd, x, sp["self"], ctx)
        return cross_fwd(x, sp["cross"], patches)

    sup = params["super"]
    vo = _self_vo(aux)
    if vo is not None:
        # rides the scans next to the self-layer params; tfm._layer's
        # body picks it up as lp["attn_vo"]
        sup = dict(sup, self=dict(sup["self"], attn_vo=vo))
    x = cm.scan_layers(super_body, x, sup, ctx)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    return cm.lm_head(cfg, params["embed"], x, ctx)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, window=None,
               dtype=jnp.bfloat16):
    ns, nself = _n_super(cfg)
    kvh, _, _ = cm.head_grid(cfg)
    hd = cfg.head_dim
    cap = min(seq_len, window) if window else seq_len
    return {
        "self": {"k": jnp.zeros((ns, nself, batch, cap, kvh, hd), dtype),
                 "v": jnp.zeros((ns, nself, batch, cap, kvh, hd), dtype)},
        "cross_k": jnp.zeros((ns, batch, cfg.vision_tokens, kvh, hd), dtype),
        "cross_v": jnp.zeros((ns, batch, cfg.vision_tokens, kvh, hd), dtype),
    }


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, *, bits=None, dtype=jnp.bfloat16):
    """Paged self-attn pool with (n_super, n_self) layer lead dims;
    cross K/V stays dense (vision prefix fixed per slot)."""
    from repro.cache import paged as paged_pool
    ns, nself = _n_super(cfg)
    kvh, _, _ = cm.head_grid(cfg)
    hd = cfg.head_dim
    return {
        "self": paged_pool.init_pool((ns, nself), n_pages, page_size, kvh,
                                     hd, dtype=dtype, bits=bits),
        "cross_k": jnp.zeros((ns, batch, cfg.vision_tokens, kvh, hd), dtype),
        "cross_v": jnp.zeros((ns, batch, cfg.vision_tokens, kvh, hd), dtype),
    }


def cache_specs(cfg: ModelConfig, ctx: ParallelContext):
    s = P(None, None, ctx.batch_spec, ctx.model_axis, None, None)
    xs = P(None, ctx.batch_spec, None, None, None)
    return {"self": {"k": s, "v": s}, "cross_k": xs, "cross_v": xs}


def precompute_cross(cfg: ModelConfig, params, patches, ctx: ParallelContext):
    """Fill cross K/V from patch embeddings (prefill-time, vision fixed)."""
    b, t, _ = patches.shape
    kvh, _, _ = cm.head_grid(cfg)
    hd = cfg.head_dim

    def per_super(sp):
        xa = sp["cross"]["xattn"]
        k = (patches @ xa["wk"]).reshape(b, t, kvh, hd)
        v = (patches @ xa["wv"]).reshape(b, t, kvh, hd)
        return k, v

    return jax.vmap(per_super)(params["super"])


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                ctx: ParallelContext, *, window=None, pages=None, aux=None):
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], ctx)

    def self_body(x, xs):
        lp, lc = xs
        h, nc = cm.attention_decode(cfg, lp["attn"],
                                    cm.apply_norm(cfg, lp["ln1"], x),
                                    lc, pos, ctx, window=window, pages=pages,
                                    vo=lp.get("attn_vo"))
        x = x + h
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path="super.self.mlp")
        return (x + h).astype(carry_dtype), nc

    def super_body(x, xs):
        sp, (sc, xk, xv) = xs
        x, nsc = jax.lax.scan(self_body, x, (sp["self"], sc))
        cp = sp["cross"]
        b = x.shape[0]
        q = (cm.apply_norm(cfg, cp["ln1"], x) @ cp["xattn"]["wq"]).reshape(
            b, 1, cm.head_grid(cfg)[2], cfg.head_dim)
        out = cm._sdpa(cfg, ctx, q, xk.astype(x.dtype), xv.astype(x.dtype),
                       None)
        x = x + jnp.tanh(cp["gate_attn"]) * (out @ cp["xattn"]["wo"])
        h = cm.mlp_forward(cfg, cp["mlp"], cm.apply_norm(cfg, cp["ln2"], x),
                           ctx, path="super.cross.mlp")
        x = x + jnp.tanh(cp["gate_mlp"]) * h
        return x.astype(carry_dtype), nsc

    carry_dtype = x.dtype
    sup = params["super"]
    vo = _self_vo(aux)
    if vo is not None:
        sup = dict(sup, self=dict(sup["self"], attn_vo=vo))
    x, nself = jax.lax.scan(
        super_body, x,
        (sup, (cache["self"],
               cache["cross_k"], cache["cross_v"])))
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.lm_head(cfg, params["embed"], x, ctx)
    return logits[:, 0], {"self": nself, "cross_k": cache["cross_k"],
                          "cross_v": cache["cross_v"]}
