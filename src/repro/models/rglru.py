"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks and local
(sliding-window) attention blocks at 2:1, each followed by a GeGLU MLP.

Pattern: superblocks of (recurrent, recurrent, local-attn); a remainder of
``num_layers % 3`` extra recurrent layers is appended (26 -> 8 super + 2).

The RG-LRU recurrence h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t) is a
first-order linear recurrence, evaluated with ``jax.lax.associative_scan``
(log-depth — the TPU-native way to parallelize a scan over sequence).
Sub-quadratic: state is O(d), so long_500k decodes natively.

Paper-technique note: the recurrence itself has no quantized TP GEMM pair
(DESIGN.md §5); MLPs use the TP-aware scheme as usual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.common import ParallelContext

_C = 8.0  # RG-LRU decay sharpness constant (Griffin paper)


# ---------------------------------------------------------------------------
# RG-LRU temporal block
# ---------------------------------------------------------------------------

def rec_block_params(cfg: ModelConfig, rng):
    d, w = cfg.d_model, cfg.lru_width
    r = cm.split_rngs(rng, ["x", "gate", "out", "ri", "ii", "lam", "conv"])
    return {
        "w_x": cm.dense_init(r["x"], (d, w)),
        "w_gate": cm.dense_init(r["gate"], (d, w)),
        "w_out": cm.dense_init(r["out"], (w, d)),
        "w_rgate": cm.dense_init(r["ri"], (w, w)),
        "w_igate": cm.dense_init(r["ii"], (w, w)),
        "lam": jnp.linspace(0.9, 5.0, w),     # softplus^-1-ish init spread
        "conv_w": cm.dense_init(r["conv"], (cfg.conv_width, w), 0.5),
    }


def rec_block_specs(cfg: ModelConfig, axis):
    return {
        "w_x": P(None, None, axis), "w_gate": P(None, None, axis),
        "w_out": P(None, axis, None),
        "w_rgate": P(None, axis, None), "w_igate": P(None, axis, None),
        "lam": P(None, axis), "conv_w": P(None, None, axis),
    }


def _causal_conv(h, conv_w, state=None):
    """Depthwise causal conv along seq.  h: (B, S, W), conv_w: (CW, W).

    ``state``: (B, CW-1, W) trailing inputs from the previous segment (decode);
    returns (out, new_state).
    """
    cw = conv_w.shape[0]
    if state is None:
        state = jnp.zeros((h.shape[0], cw - 1, h.shape[2]), h.dtype)
    hist = jnp.concatenate([state, h], axis=1)          # (B, S+CW-1, W)
    out = jnp.zeros_like(h)
    for i in range(cw):
        out = out + hist[:, i:i + h.shape[1]] * conv_w[cw - 1 - i]
    new_state = hist[:, -(cw - 1):]
    return out, new_state


def _rg_lru(h, r_gate, i_gate, lam, state=None):
    """h: (B, S, W) -> (out, last_state).  a_t = exp(-c*softplus(lam)*r_t)."""
    r = jax.nn.sigmoid(r_gate)
    i = jax.nn.sigmoid(i_gate)
    log_a = -_C * jax.nn.softplus(lam) * r                  # (B, S, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6)) * (i * h)

    if h.shape[1] == 1:  # decode fast path
        s0 = state if state is not None else jnp.zeros_like(h[:, 0])
        s1 = a[:, 0] * s0 + gated[:, 0]
        return s1[:, None], s1

    if state is not None:
        gated = gated.at[:, 0].add(a[:, 0] * state)

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, out = jax.lax.associative_scan(comb, (a, gated), axis=1)
    return out, out[:, -1]


def rec_block_forward(cfg: ModelConfig, p, x, ctx: ParallelContext,
                      state=None):
    """state: {"conv": (B, CW-1, W), "lru": (B, W)} or None (training)."""
    xb = x @ p["w_x"]
    xb = ctx.shard(xb, ctx.batch_spec, None, ctx.model_axis)
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)

    conv_state = state["conv"] if state is not None else None
    xb, new_conv = _causal_conv(xb, p["conv_w"], conv_state)
    r_gate = xb @ p["w_rgate"]
    i_gate = xb @ p["w_igate"]
    lru_state = state["lru"] if state is not None else None
    h, new_lru = _rg_lru(xb.astype(jnp.float32), r_gate.astype(jnp.float32),
                         i_gate.astype(jnp.float32), p["lam"], lru_state)
    h = h.astype(x.dtype) * gate
    h = ctx.shard(h, ctx.batch_spec, None, ctx.model_axis)
    y = h @ p["w_out"]
    y = ctx.shard(y, ctx.batch_spec, None, None)
    new_state = {"conv": new_conv, "lru": new_lru}
    return y, new_state


def init_rec_state(cfg: ModelConfig, n_layers: int, batch: int,
                   dtype=jnp.bfloat16):
    w = cfg.lru_width
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1, w), dtype),
        "lru": jnp.zeros((n_layers, batch, w), jnp.float32),
    }


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _n_super(cfg):
    return cfg.num_layers // 3, cfg.num_layers % 3


def init_params(cfg: ModelConfig, rng):
    r = cm.split_rngs(rng, ["embed", "super", "extra", "norm"])
    ns, nx = _n_super(cfg)

    def make_rec_layer(lr):
        lrs = cm.split_rngs(lr, ["rec", "mlp"])
        return {
            "ln1": cm.norm_params(cfg),
            "rec": rec_block_params(cfg, lrs["rec"]),
            "ln2": cm.norm_params(cfg),
            "mlp": cm.mlp_params(cfg, lrs["mlp"]),
        }

    def make_super(lr):
        lrs = cm.split_rngs(lr, ["r1", "r2", "attn", "mlp"])
        return {
            "rec1": make_rec_layer(lrs["r1"]),
            "rec2": make_rec_layer(lrs["r2"]),
            "attn": {
                "ln1": cm.norm_params(cfg),
                "attn": cm.attention_params(cfg, lrs["attn"]),
                "ln2": cm.norm_params(cfg),
                "mlp": cm.mlp_params(cfg, lrs["mlp"]),
            },
        }

    return {
        "embed": cm.embed_params(cfg, r["embed"]),
        "super": cm.stack_layer_params(make_super, r["super"], ns),
        "extra": cm.stack_layer_params(make_rec_layer, r["extra"], nx)
        if nx else None,
        "final_norm": cm.norm_params(cfg),
    }


def param_specs(cfg: ModelConfig, params, ctx: ParallelContext):
    axis = ctx.model_axis
    norm = {"scale": P(None, None)}

    def rec_layer_specs(p):
        return {
            "ln1": dict(norm), "rec": rec_block_specs(cfg, axis),
            "ln2": dict(norm),
            "mlp": cm.mlp_specs(cfg, p["mlp"], axis),
        }

    sup = params["super"]
    specs = {
        "embed": cm.embed_specs(cfg, axis, ctx.axis_size(axis)),
        "super": {
            "rec1": rec_layer_specs(sup["rec1"]),
            "rec2": rec_layer_specs(sup["rec2"]),
            "attn": {
                "ln1": dict(norm),
                "attn": cm.attention_specs(cfg, axis),
                "ln2": dict(norm),
                "mlp": cm.mlp_specs(cfg, sup["attn"]["mlp"], axis),
            },
        },
        "extra": (rec_layer_specs(params["extra"])
                  if params["extra"] is not None else None),
        "final_norm": {"scale": P(None)},
    }
    return specs


def _rec_layer_fwd(cfg, ctx):
    def body(x, lp, state, path):
        h, ns = rec_block_forward(cfg, lp["rec"],
                                  cm.apply_norm(cfg, lp["ln1"], x), ctx,
                                  state)
        x = x + h
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path=path)
        return x + h, ns
    return body


def forward(cfg: ModelConfig, params, batch, ctx: ParallelContext, *,
            window=None):
    x = cm.embed_tokens(cfg, params["embed"], batch["tokens"], ctx)
    rec_fwd = _rec_layer_fwd(cfg, ctx)

    def super_body(x, sp, _):
        x, _s = rec_fwd(x, sp["rec1"], None, path="super.rec1.mlp")
        x, _s = rec_fwd(x, sp["rec2"], None, path="super.rec2.mlp")
        ap = sp["attn"]
        h = cm.attention_forward(cfg, ap["attn"],
                                 cm.apply_norm(cfg, ap["ln1"], x), ctx,
                                 window=cfg.local_window)
        x = x + h
        h = cm.mlp_forward(cfg, ap["mlp"], cm.apply_norm(cfg, ap["ln2"], x),
                           ctx, path="super.attn.mlp")
        return x + h

    x = cm.scan_layers(super_body, x, params["super"], ctx)
    if params["extra"] is not None:
        def extra_body(x, lp, _):
            y, _s = rec_fwd(x, lp, None, path="extra.mlp")
            return y
        x = cm.scan_layers(extra_body, x, params["extra"], ctx)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    return cm.lm_head(cfg, params["embed"], x, ctx)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, window=None,
               dtype=jnp.bfloat16):
    ns, nx = _n_super(cfg)
    cap = min(seq_len, cfg.local_window)
    return {
        "rec1": init_rec_state(cfg, ns, batch, dtype),
        "rec2": init_rec_state(cfg, ns, batch, dtype),
        "attn": cm.init_kv_cache(cfg, ns, batch, cap, window=cfg.local_window,
                                 dtype=dtype),
        "extra": init_rec_state(cfg, nx, batch, dtype) if nx else None,
    }


def cache_specs(cfg: ModelConfig, ctx: ParallelContext):
    rec = {"conv": P(None, ctx.batch_spec, None, ctx.model_axis),
           "lru": P(None, ctx.batch_spec, ctx.model_axis)}
    return {
        "rec1": dict(rec), "rec2": dict(rec),
        "attn": cm.kv_cache_specs(cfg, ctx),
        "extra": (dict(rec) if _n_super(cfg)[1] else None),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                ctx: ParallelContext, *, window=None, pages=None):
    # ``pages`` accepted for interface uniformity and ignored: the local
    # ring-buffer KV is already fixed-size per slot (state-like) and the
    # recurrent conv/lru state has no sequence dim — nothing to page.
    del pages
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], ctx)
    rec_fwd = _rec_layer_fwd(cfg, ctx)

    def super_body(x, xs):
        sp, (c1, c2, ca) = xs
        x, n1 = rec_fwd(x, sp["rec1"], c1, path="super.rec1.mlp")
        x, n2 = rec_fwd(x, sp["rec2"], c2, path="super.rec2.mlp")
        ap = sp["attn"]
        h, nca = cm.attention_decode(cfg, ap["attn"],
                                     cm.apply_norm(cfg, ap["ln1"], x),
                                     ca, pos, ctx, window=cfg.local_window)
        x = x + h
        h = cm.mlp_forward(cfg, ap["mlp"], cm.apply_norm(cfg, ap["ln2"], x),
                           ctx, path="super.attn.mlp")
        return (x + h).astype(carry_dtype), (n1, n2, nca)

    carry_dtype = x.dtype
    x, (nc1, nc2, nca) = jax.lax.scan(
        super_body, x,
        (params["super"], (cache["rec1"], cache["rec2"], cache["attn"])))
    new_cache = {"rec1": nc1, "rec2": nc2, "attn": nca, "extra": None}

    if params["extra"] is not None:
        def extra_body(x, xs):
            lp, st = xs
            y, ns = rec_fwd(x, lp, st, path="extra.mlp")
            return y.astype(carry_dtype), ns
        x, nex = jax.lax.scan(extra_body, x, (params["extra"], cache["extra"]))
        new_cache["extra"] = nex

    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.lm_head(cfg, params["embed"], x, ctx)
    return logits[:, 0], new_cache
