"""Generic dense decoder-only transformer (llama/qwen/mistral/starcoder/
granite families): pre-norm GQA attention + (optionally quantized) MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.common import ParallelContext


def init_params(cfg: ModelConfig, rng):
    r = cm.split_rngs(rng, ["embed", "layers", "norm"])

    def make_layer(lr):
        lrs = cm.split_rngs(lr, ["attn", "mlp"])
        return {
            "ln1": cm.norm_params(cfg),
            "attn": cm.attention_params(cfg, lrs["attn"]),
            "ln2": cm.norm_params(cfg),
            "mlp": cm.mlp_params(cfg, lrs["mlp"]),
        }

    return {
        "embed": cm.embed_params(cfg, r["embed"]),
        "layers": cm.stack_layer_params(make_layer, r["layers"],
                                        cfg.num_layers),
        "final_norm": cm.norm_params(cfg),
    }


def param_specs(cfg: ModelConfig, params, ctx: ParallelContext):
    axis = ctx.model_axis
    norm = {"scale": P(None, None)} if cfg.norm_type == "rms" else \
        {"scale": P(None, None), "bias": P(None, None)}
    return {
        "embed": cm.embed_specs(cfg, axis, ctx.axis_size(axis)),
        "layers": {
            "ln1": dict(norm),
            "attn": cm.attention_specs(cfg, axis),
            "ln2": dict(norm),
            "mlp": cm.mlp_specs(cfg, params["layers"]["mlp"], axis),
        },
        "final_norm": {k: P(None) for k in
                       (("scale", "bias") if cfg.norm_type == "layernorm"
                        else ("scale",))},
    }


#: this family consumes precompiled attention V->O folds (artifact aux
#: plans) — the registry only forwards ``aux`` to modules that declare it.
SUPPORTS_ATTN_VO = True

#: dotted path ``stage_fold_attention`` records this family's attention
#: dicts under (the key into the artifact's aux ``attn_plans``).
ATTN_VO_PATH = "layers.attn"


def _layer_vo(aux):
    """The stacked V->O ``PlannedPair`` for this family's layers, if the
    artifact carried one (scanned alongside the layer params)."""
    if not aux:
        return None
    return (aux.get("attn_plans") or {}).get(ATTN_VO_PATH)


def _layer(cfg, ctx, window, mlp_path="layers.mlp"):
    def body(x, lp, _):
        h = cm.attention_forward(cfg, lp["attn"],
                                 cm.apply_norm(cfg, lp["ln1"], x), ctx,
                                 window=window, causal=cfg.causal,
                                 vo=lp.get("attn_vo"))
        x = x + h
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path=mlp_path)
        return x + h
    return body


def forward(cfg: ModelConfig, params, batch, ctx: ParallelContext, *,
            window=None, aux=None):
    """Train/prefill forward: batch={"tokens": (B, S)} -> logits."""
    x = cm.embed_tokens(cfg, params["embed"], batch["tokens"], ctx)
    layers = params["layers"]
    vo = _layer_vo(aux)
    if vo is not None:
        layers = dict(layers, attn_vo=vo)
    x = cm.scan_layers(_layer(cfg, ctx, window), x, layers, ctx)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    return cm.lm_head(cfg, params["embed"], x, ctx)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, window=None,
               dtype=jnp.bfloat16):
    return cm.init_kv_cache(cfg, cfg.num_layers, batch, seq_len,
                            window=window, dtype=dtype)


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, *, bits=None, dtype=jnp.bfloat16):
    del batch  # pure pool: per-slot state lives in the page table
    return cm.init_paged_kv_cache(cfg, cfg.num_layers, n_pages, page_size,
                                  bits=bits, dtype=dtype)


def cache_specs(cfg: ModelConfig, ctx: ParallelContext):
    return cm.kv_cache_specs(cfg, ctx)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                ctx: ParallelContext, *, window=None, pages=None, aux=None):
    """One-token decode. tokens: (B,), pos: scalar -> (logits (B, V), cache)."""
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], ctx)

    def body(x, lp, lc, _):
        h, nc = cm.attention_decode(cfg, lp["attn"],
                                    cm.apply_norm(cfg, lp["ln1"], x),
                                    lc, pos, ctx, window=window, pages=pages,
                                    vo=lp.get("attn_vo"))
        x = x + h
        h = cm.mlp_forward(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x),
                           ctx, path="layers.mlp")
        return x + h, nc

    layers = params["layers"]
    vo = _layer_vo(aux)
    if vo is not None:
        layers = dict(layers, attn_vo=vo)
    x, new_cache = cm.scan_layers_cache(body, x, layers, cache, ctx)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.lm_head(cfg, params["embed"], x, ctx)
    return logits[:, 0], new_cache
