"""Shared model substrate: norms, RoPE, GQA attention, parallel MLP.

All models are pure functions over nested-dict param pytrees.  Layers are
stacked along a leading L dim and driven by ``jax.lax.scan`` so that a
100-layer full config traces/lower as one layer.

Parallelism is carried by a ``ParallelContext``:
* ``mesh is None`` — single-device reference semantics (smoke tests),
* otherwise GSPMD sharding constraints are applied throughout, and the
  quantized MLP pairs run the paper's explicit-collective ``shard_map``
  schemes over the ``model`` axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import compat, schemes
from repro.core.policy import DEFAULT_POLICY, ExecutionPolicy
from repro.core.reorder import PlannedPair


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[jax.sharding.Mesh] = None
    model_axis: str = "model"
    batch_axes: tuple = ("data",)
    shard_map_mlp: bool = True     # paper's explicit-collective MLP path
    remat: bool = False
    # The deployment plan the quantized MLP pairs execute under (kernel
    # backend, compute dtype, collective spec).  None means the historical
    # defaults (DEFAULT_POLICY: tp-aware / jnp / f32 / psum).
    policy: Optional[ExecutionPolicy] = None
    # Long-seq attention Q-chunking: lax.scan over chunks (True, memory-
    # bounded — the deployment default) or a python-unrolled loop (False —
    # used by the dry-run cost probes, because XLA's cost_analysis counts a
    # scan body only once).
    chunk_scan: bool = True
    # attention backend: "xla" (einsum path, used by the dry-run so
    # cost_analysis sees the FLOPs) or "flash" (fused Pallas kernel —
    # the TPU deployment path; interpret=True on CPU)
    attn_backend: str = "xla"

    @property
    def execution_policy(self) -> ExecutionPolicy:
        """The effective deployment plan: ``policy`` when set, else the
        historical defaults."""
        return self.policy if self.policy is not None else DEFAULT_POLICY

    def shard(self, x: jax.Array, *spec) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    @property
    def batch_spec(self):
        return self.batch_axes if self.batch_axes else None

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.axis_names:
            return 1
        return dict(zip(self.mesh.axis_names,
                        self.mesh.devices.shape))[name]

    @property
    def ep_axis(self):
        """Expert-parallel axis: the innermost batch axis; falls back to
        'data' when the batch itself is unsharded (e.g. batch=1 decode) —
        EP sharding of the expert *weights* never requires a sharded
        batch."""
        if self.batch_axes:
            return self.batch_axes[-1]
        if self.mesh is not None and "data" in self.mesh.axis_names:
            return "data"
        return None


REPLICATED = ParallelContext()


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def split_rngs(rng, names):
    keys = jax.random.split(rng, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def norm_params(cfg: ModelConfig, shape=None):
    d = shape or (cfg.d_model,)
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones(d), "bias": jnp.zeros(d)}
    return {"scale": jnp.ones(d)}


def apply_norm(cfg: ModelConfig, p, x):
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps):
    """Per-head RMS norm (qwen3 qk_norm); x: (..., D), scale: (D,)."""
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (B, S, H, D), positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
        ang = ang[None, :, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def head_grid(cfg: ModelConfig) -> tuple[int, int, int]:
    """(kv_pad, g_pad, h_pad): the deployed (KV, group) head grid.

    Without ``cfg.attn_tp_pad``, this is the logical (kv, g, h).  With it,
    the grid is minimally padded so ``h_pad % attn_tp_pad == 0`` — e.g.
    starcoder2's (2, 12, 24) becomes (2, 16, 32) on a 16-way axis.  Padded
    q/kv heads carry zero weights and zero wo rows, so the computed
    function is exactly the logical architecture's (see DESIGN.md §4).
    """
    kv, h = cfg.n_kv_heads, cfg.n_heads
    g = h // kv
    tp = cfg.attn_tp_pad
    if not tp or h % tp == 0:
        return kv, g, h
    best = None
    for gp in range(g, g + tp + 1):
        for kvp in range(kv, kv + tp + 1):
            if (kvp * gp) % tp == 0:
                if best is None or kvp * gp < best[0] * best[1]:
                    best = (kvp, gp)
                break
    kvp, gp = best
    return kvp, gp, kvp * gp


def _pad_heads(w: jax.Array, d: int, n_real: int, n_pad: int, hd: int,
               *, axis_last: bool = True) -> jax.Array:
    """Zero-pad a (d, n_real*hd) projection to (d, n_pad*hd) head-wise."""
    if n_real == n_pad:
        return w
    if axis_last:
        w = w.reshape(d, n_real, hd)
        w = jnp.pad(w, ((0, 0), (0, n_pad - n_real), (0, 0)))
        return w.reshape(d, n_pad * hd)
    w = w.reshape(n_real, hd, d)
    w = jnp.pad(w, ((0, n_pad - n_real), (0, 0), (0, 0)))
    return w.reshape(n_pad * hd, d)


def attention_params(cfg: ModelConfig, rng, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    kvp, gp, hp = head_grid(cfg)
    g = h // kv
    r = split_rngs(rng, ["q", "k", "v", "o", "qn", "kn"])
    # init the logical heads, zero-pad to the deployed grid (kv-major
    # blocks: q head (kv_i, g_j) pairs with kv head kv_i after repeat)
    wq = dense_init(r["q"], (d, kv, g, hd)).reshape(d, h * hd)
    if (kvp, gp) != (kv, g):
        wq4 = wq.reshape(d, kv, g, hd)
        wq4 = jnp.pad(wq4, ((0, 0), (0, kvp - kv), (0, gp - g), (0, 0)))
        wq = wq4.reshape(d, hp * hd)
    wo = dense_init(r["o"], (kv, g, hd, d)).reshape(h * hd, d)
    if (kvp, gp) != (kv, g):
        wo4 = wo.reshape(kv, g, hd, d)
        wo4 = jnp.pad(wo4, ((0, kvp - kv), (0, gp - g), (0, 0), (0, 0)))
        wo = wo4.reshape(hp * hd, d)
    p = {
        "wq": wq,
        "wk": _pad_heads(dense_init(r["k"], (d, kv * hd)), d, kv, kvp, hd),
        "wv": _pad_heads(dense_init(r["v"], (d, kv * hd)), d, kv, kvp, hd),
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones(hd)
        p["k_norm"] = jnp.ones(hd)
    return p


def attention_specs(cfg: ModelConfig, axis="model", stacked=True):
    lead = (None,) if stacked else ()
    p = {
        "wq": P(*lead, None, axis), "wk": P(*lead, None, axis),
        "wv": P(*lead, None, axis), "wo": P(*lead, axis, None),
    }
    if cfg.qk_norm:
        p["q_norm"] = P(*lead, None)
        p["k_norm"] = P(*lead, None)
    return p


def _sdpa(cfg: ModelConfig, ctx: ParallelContext, q, k, v, mask):
    """Scaled-dot-product attention in flat-head (Megatron head-TP) form.

    q: (B, S, H, D); k/v: (B, T, KV, D); mask: broadcastable to (B,?,S,T).

    GQA KV heads are broadcast to H before the einsums so the *head* dim is
    the contraction-free dim everywhere — it then shards cleanly over the
    model axis (GSPMD pads when H % tp != 0, e.g. whisper's 20 heads on a
    16-way axis).  Keeping the (group, kv) split instead would leave score
    tensors with dims 12/8/2... that a 16-way axis cannot shard at all,
    replicating the S×T score tile on every rank — 16× redundant FLOPs and
    an HBM blow-up at 32k prefill (measured; see DESIGN.md §4).  XLA fuses
    the jnp.repeat broadcast into the dots, so no repeated KV is
    materialized.
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / (d ** 0.5)
    scores = scores.astype(jnp.float32)
    if s == 1:
        # decode: key-parallel — scores shard over the cache/T dim so the
        # (long) KV cache is never gathered across the model axis; the
        # trailing partial-sum all-reduce on out is tiny (one token).
        scores = ctx.shard(scores, ctx.batch_spec, None, None,
                           ctx.model_axis)
    else:
        # prefill/train: head-parallel (the padded grid shards exactly)
        scores = ctx.shard(scores, ctx.batch_spec, ctx.model_axis, None,
                           None)
    if mask is not None:
        scores = jnp.where(mask[:, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v).reshape(b, s, h * d)
    return out


def _flash_sdpa(cfg: ModelConfig, ctx: ParallelContext, q, k, v, *,
                causal: bool, window):
    """Fused flash-attention path (Pallas kernel; kernels/flash_attention).

    Embarrassingly parallel over (batch, heads) after head-grid padding, so
    under a mesh it runs inside shard_map with batch over the data axes and
    heads over the model axis — zero attention collectives, no S×T score
    HBM round-trip (the memory-term hillclimb; EXPERIMENTS.md §Perf).
    """
    from repro.kernels import ops

    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    # repeat KV to the full (padded) head grid BEFORE sharding so each
    # rank's q-head slice pairs with its own kv copies (kv-major layout)
    qt = q.transpose(0, 2, 1, 3)                         # (b, h, s, d)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
    bq = min(128, s)

    def local(qb, kb, vb):
        return ops.flash_attention(qb, kb, vb, causal=causal,
                                   window=window, block_q=bq, block_k=bq)

    if ctx.mesh is None:
        out = local(qt, kt, vt)
    else:
        spec = P(ctx.batch_spec, ctx.model_axis, None, None)
        out = compat.shard_map(
            local, mesh=ctx.mesh,
            in_specs=(spec, spec, spec),
            out_specs=spec,
        )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


#: Q-chunk size for long-sequence attention: the (qc, T) score tile is the
#: only S×T-scaling temp, so prefill at 32k fits VMEM/HBM.  Chunking runs a
#: *python* loop (unrolled HLO), so the dry-run's cost analysis counts every
#: chunk — a lax.scan here would be invisible to cost_analysis.
Q_CHUNK = 2048
Q_CHUNK_MIN_SEQ = 8192


def _vo_project_v(vo: PlannedPair, src, policy) -> jax.Array:
    """V projection through a precompiled V->O fold (``attention_fold``):
    gather the input by P1, run the folded quantized up GEMM.  The output
    channels are permuted *within each KV-head block* — attention mixes
    tokens, never channels, so the mix commutes and ``vo.down`` (whose
    sorted rows expect exactly this order) closes the pair."""
    xin = (jnp.take(src, vo.p1_up, axis=-1)
           if vo.p1_up is not None else src)
    return schemes.qmatmul(xin, vo.up, policy).astype(src.dtype)


def attention_forward(cfg: ModelConfig, p, x, ctx: ParallelContext, *,
                      positions=None, window=None, kv_x=None, causal=True,
                      vo: Optional[PlannedPair] = None):
    """Full-sequence attention (training / prefill / encoder / cross).

    ``kv_x``: source sequence for cross-attention (defaults to x).
    Long self-attention (S >= Q_CHUNK_MIN_SEQ) is Q-chunked: each chunk's
    softmax row sees the full key range, so the result is exact (no online
    rescaling needed), while the materialized score tile shrinks from
    (S, T) to (Q_CHUNK, T).

    ``vo``: optional precompiled V->O fold (``core/attention_fold``, the
    artifact's aux plans).  The V and O projections then run as quantized
    GEMMs over the folded layout instead of ``p["wv"]``/``p["wo"]`` —
    channel order inside each KV-head block is permuted, which attention's
    token-mixing commutes with, so the closed pair is the planned
    (quantized) function of the same architecture.
    """
    b, s, dm = x.shape
    hd = cfg.head_dim
    kvh, _, h = head_grid(cfg)          # deployed (possibly padded) grid
    src = kv_x if kv_x is not None else x
    t = src.shape[1]

    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (src @ p["wk"]).reshape(b, t, kvh, hd)
    if vo is not None:
        v = _vo_project_v(vo, src, ctx.execution_policy)
        v = v.reshape(b, t, kvh, hd)
    else:
        v = (src @ p["wv"]).reshape(b, t, kvh, hd)
    q = ctx.shard(q, ctx.batch_spec, None, ctx.model_axis, None)
    k = ctx.shard(k, ctx.batch_spec, None, None, None)
    v = ctx.shard(v, ctx.batch_spec, None, None, None)

    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_head_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope and kv_x is None:
        if positions is None:
            positions = jnp.arange(s)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    def mask_rows(i0, rows: int):
        if not (causal and kv_x is None):
            return None
        i = (i0 + jnp.arange(rows))[:, None]
        j = jnp.arange(t)[None, :]
        m = j <= i
        if window is not None:
            m = m & (j > i - window)
        return jnp.broadcast_to(m, (b, rows, t))

    if ctx.attn_backend == "flash" and kv_x is None:
        out = _flash_sdpa(cfg, ctx, q, k, v, causal=causal, window=window)
    elif (causal and kv_x is None and s >= Q_CHUNK_MIN_SEQ
            and s % Q_CHUNK == 0):
        nc = s // Q_CHUNK
        if ctx.chunk_scan:
            qs = q.reshape(b, nc, Q_CHUNK, h, hd).swapaxes(0, 1)

            def chunk_body(carry, xs):
                ci, qch = xs
                o = _sdpa(cfg, ctx, qch, k, v, mask_rows(ci * Q_CHUNK,
                                                         Q_CHUNK))
                return carry, o

            _, outs = jax.lax.scan(chunk_body, None,
                                   (jnp.arange(nc), qs))
            out = outs.swapaxes(0, 1).reshape(b, s, -1)
        else:
            outs = [_sdpa(cfg, ctx, q[:, i0:i0 + Q_CHUNK], k, v,
                          mask_rows(i0, Q_CHUNK))
                    for i0 in range(0, s, Q_CHUNK)]
            out = jnp.concatenate(outs, axis=1)
    else:
        out = _sdpa(cfg, ctx, q, k, v, mask_rows(0, s))
    out = ctx.shard(out, ctx.batch_spec, None, ctx.model_axis)
    y = _attn_out_proj(p, out, vo, ctx, x.dtype)
    return ctx.shard(y, ctx.batch_spec, None, None)


def attention_decode(cfg: ModelConfig, p, x, cache, pos, ctx: ParallelContext,
                     *, window=None, pages=None,
                     vo: Optional[PlannedPair] = None):
    """One-token decode with KV cache.

    x: (B, 1, d); cache: {"k","v": (B, C, KV, D)} where C = cache capacity
    (full seq_len, or ``window`` for the ring-buffer variant); pos: the
    current position — a scalar (all requests in lockstep, the historical
    path) or a (B,) vector of *per-slot* positions (continuous batching:
    the scheduler admits a new request into a retired slot mid-stream, so
    each slot runs its own clock).  Returns (out, new_cache).

    ``pages``: (B, Pmax) int32 per-slot page table — the cache is then a
    page *pool* {"k","v": (N_pages, page_size, KV, D)} (plus scale/zero
    leaves for quantized pages; ``repro.cache.paged``) instead of dense
    per-slot rows: the new token scatters into
    ``(pages[b, pos // ps], pos % ps)`` and K/V are gathered back by page
    index.  Masked gather columns (pos < j, including whole unallocated
    pages aliased to page 0) score -1e30, whose exp underflows to exactly
    0.0 in f32 — so the padded tail never contributes and paged decode is
    bit-identical to dense for fp pools, at any page size.
    """
    b = x.shape[0]
    hd = cfg.head_dim
    kvh, _, h = head_grid(cfg)          # deployed (possibly padded) grid
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1            # (B,) per-slot clocks

    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, kvh, hd)
    if vo is not None:
        # folded V channels land in the cache; every read goes through
        # vo.down whose rows expect exactly this order (see
        # attention_forward) — so the cache layout is self-consistent.
        v = _vo_project_v(vo, x, ctx.execution_policy)
        v = v.reshape(b, 1, kvh, hd)
    else:
        v = (x @ p["wv"]).reshape(b, 1, kvh, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_head_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        posv = pos[:, None] if per_slot else jnp.full((1,), pos, jnp.int32)
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)

    if pages is not None:
        from repro.cache import paged as paged_pool

        if window is not None:
            raise ValueError("paged decode does not take a ring-buffer "
                             "window (windowed caches are fixed-size per "
                             "slot and stay dense)")
        if not per_slot:
            raise ValueError("paged decode requires per-slot (B,) "
                             "positions (the page table is per slot)")
        new_cache = paged_pool.scatter_token(cache, k[:, 0], v[:, 0],
                                             pages, pos)
        kk, vv = paged_pool.gather(new_cache, pages)   # (B, T, KV, D)
        t = kk.shape[1]
        valid = jnp.arange(t)[None, :] <= pos[:, None]
        mask = jnp.broadcast_to(valid[:, None, :], (b, 1, t))
        kk = ctx.shard(kk, ctx.batch_spec, ctx.model_axis, None, None)
        vv = ctx.shard(vv, ctx.batch_spec, ctx.model_axis, None, None)
        q = ctx.shard(q, ctx.batch_spec, None, ctx.model_axis, None)
        out = _sdpa(cfg, ctx, q, kk.astype(x.dtype), vv.astype(x.dtype),
                    mask)
        y = _attn_out_proj(p, out, vo, ctx, x.dtype)
        return ctx.shard(y, ctx.batch_spec, None, None), new_cache

    cap = cache["k"].shape[1]
    slot = pos % cap if window is not None else pos
    if per_slot:
        # per-slot scatter: each batch row writes its own cache position
        ck = cache["k"].at[jnp.arange(b), slot].set(
            k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[jnp.arange(b), slot].set(
            v[:, 0].astype(cache["v"].dtype))
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    # shard the cache along its (long) sequence dim over the model axis —
    # KV heads may be fewer than the axis size (GQA), sequence never is.
    ck = ctx.shard(ck, ctx.batch_spec, ctx.model_axis, None, None)
    cv = ctx.shard(cv, ctx.batch_spec, ctx.model_axis, None, None)

    j = jnp.arange(cap)
    pb = pos[:, None] if per_slot else pos          # (B, 1) | scalar
    if window is not None:
        # ring buffer: once pos >= cap every slot holds one of the last
        # `cap` positions; before that only slots <= pos are valid.
        valid = (j[None, :] <= pb) | jnp.broadcast_to(
            jnp.asarray(pb >= cap), (pb.shape[0] if per_slot else 1, cap))
    else:
        valid = jnp.broadcast_to(j[None, :] <= pb,
                                 (pb.shape[0] if per_slot else 1, cap))
    mask = jnp.broadcast_to(valid[:, None, :], (b, 1, cap))

    q = ctx.shard(q, ctx.batch_spec, None, ctx.model_axis, None)
    out = _sdpa(cfg, ctx, q, ck.astype(x.dtype), cv.astype(x.dtype), mask)
    y = _attn_out_proj(p, out, vo, ctx, x.dtype)
    return ctx.shard(y, ctx.batch_spec, None, None), {"k": ck, "v": cv}


def _attn_out_proj(p, out, vo: Optional[PlannedPair], ctx, dtype):
    if vo is not None:
        return schemes.qmatmul(out, vo.down,
                               ctx.execution_policy).astype(dtype)
    return out @ p["wo"]


def init_kv_cache(cfg: ModelConfig, num_layers: int, batch: int, seq_len: int,
                  *, window=None, dtype=jnp.bfloat16):
    cap = min(seq_len, window) if window else seq_len
    kvp, _, _ = head_grid(cfg)
    shape = (num_layers, batch, cap, kvp, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_cache(cfg: ModelConfig, num_layers: int, n_pages: int,
                        page_size: int, *, bits=None, dtype=jnp.bfloat16):
    """Layer-stacked page pool replacing ``init_kv_cache``'s dense rows:
    leaves (L, N_pages, page_size, KVp, D) — see ``repro.cache.paged``."""
    from repro.cache import paged as paged_pool
    kvp, _, _ = head_grid(cfg)
    return paged_pool.init_pool((num_layers,), n_pages, page_size, kvp,
                                cfg.head_dim, dtype=dtype, bits=bits)


def kv_cache_specs(cfg: ModelConfig, ctx: ParallelContext):
    s = P(None, ctx.batch_spec, ctx.model_axis, None, None)
    return {"k": s, "v": s}


# ---------------------------------------------------------------------------
# MLP — the paper's subject
# ---------------------------------------------------------------------------

def mlp_params(cfg: ModelConfig, rng, *, d_ff=None):
    """One layer's raw (dense fp) MLP params.

    Model init always emits raw weights now — quantization and layout
    planning happen in ONE place, the offline plan compiler
    (``plan/compiler.py``), which ``registry.Model.init`` runs in memory
    when ``cfg.quant.mode == "mlp"`` (and which ``prepare`` runs ahead of
    time into a ``DeploymentArtifact``).  The 4-way rng split is kept so
    dense weights stay bit-identical to the historical init stream.
    """
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    r = split_rngs(rng, ["up", "gate", "down", "plan"])
    p = {"w_up": dense_init(r["up"], (d, ff)),
         "w_down": dense_init(r["down"], (ff, d))}
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(r["gate"], (d, ff))
    return p


def mlp_specs(cfg: ModelConfig, params_like, axis="model", stacked=True,
              lead=None):
    """PartitionSpecs for one (possibly stacked) MLP param tree.

    ``lead``: explicit leading-dim spec entries (overrides ``stacked``) —
    e.g. ``(None, "data")`` for MoE experts stacked (L, E, ...) with E
    expert-parallel over the data axis.
    """
    if lead is None:
        lead = (None,) if stacked else ()
    if isinstance(params_like, PlannedPair):
        specs = schemes.pair_pspecs(params_like, axis)
        # prepend the stacking dim to every leaf spec
        def addlead(s):
            return P(*lead, *s) if isinstance(s, P) else s
        return jax.tree.map(addlead, specs,
                            is_leaf=lambda x: isinstance(x, P))
    out = {"w_up": P(*lead, None, axis), "w_down": P(*lead, axis, None)}
    if "w_gate" in params_like:
        out["w_gate"] = P(*lead, None, axis)
    return out


def mlp_forward(cfg: ModelConfig, p, x, ctx: ParallelContext, *,
                activation=None, path=None):
    """Apply an MLP block (quantized via the paper's schemes, or dense).

    ``path`` is the pair's dotted param path (e.g. ``"layers.mlp"``) —
    the key a per-layer ``CollectivePlan`` resolves this epilogue's
    collective by; model layer bodies pass the same path the plan
    compiler records in the artifact manifest."""
    act = activation or cfg.activation
    if isinstance(p, PlannedPair):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        pol = ctx.execution_policy
        if ctx.mesh is not None and ctx.shard_map_mlp:
            y = p.forward(x2, pol, ctx.mesh, axis=ctx.model_axis,
                          batch_axes=ctx.batch_axes, activation=act,
                          pair_path=path)
        else:
            y = p.forward(x2, pol, activation=act)
        return y.reshape(*lead, -1).astype(x.dtype)
    a = schemes.ACTIVATIONS[act]
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = a(x @ p["w_gate"]) * h
    else:
        h = a(h)
    h = ctx.shard(h, ctx.batch_spec, None, ctx.model_axis)
    y = h @ p["w_down"]
    return ctx.shard(y, ctx.batch_spec, None, None)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_params(cfg: ModelConfig, rng):
    r = split_rngs(rng, ["emb", "head"])
    v, vp = cfg.vocab_size, cfg.padded_vocab()
    emb = dense_init(r["emb"], (v, cfg.d_model), 1.0)
    head = dense_init(r["head"], (cfg.d_model, v))
    if vp != v:
        emb = jnp.pad(emb, ((0, vp - v), (0, 0)))
        head = jnp.pad(head, ((0, 0), (0, vp - v)))
    return {"embedding": emb, "lm_head": head}


def embed_specs(cfg: ModelConfig, axis="model", axis_size: int = 16):
    """Vocab-dim sharding when it divides the axis (jit *arguments* must
    shard exactly; intermediates may be padded); else shard d_model.
    With deployment vocab padding (cfg.padded_vocab) the vocab dim always
    shards — avoiding the full-logits psum the d_model fallback costs."""
    if cfg.padded_vocab() % axis_size == 0:
        return {"embedding": P(axis, None), "lm_head": P(None, axis)}
    return {"embedding": P(None, axis), "lm_head": P(axis, None)}


def embed_tokens(cfg, p, tokens, ctx: ParallelContext):
    x = jnp.take(p["embedding"], tokens, axis=0)
    return ctx.shard(x.astype(jnp.bfloat16)
                     if cfg.dtype == "bfloat16" else x,
                     ctx.batch_spec, None, None)


def lm_head(cfg, p, x, ctx: ParallelContext):
    logits = x.astype(jnp.float32) @ p["lm_head"].astype(jnp.float32)
    v, vp = cfg.vocab_size, cfg.padded_vocab()
    if vp != v:
        # mask padded vocab columns: exp(-1e30) == 0, softmax/loss exact
        mask = jnp.where(jnp.arange(vp) < v, 0.0, -1e30)
        logits = logits + mask
    return ctx.shard(logits, ctx.batch_spec, None, ctx.model_axis)


# ---------------------------------------------------------------------------
# layer scan helper
# ---------------------------------------------------------------------------

def scan_layers(body, x, stacked_params, ctx: ParallelContext, extra=None):
    """Scan ``body(x, layer_params, extra) -> x`` over stacked layers."""
    fn = body
    if ctx.remat:
        fn = jax.checkpoint(body)

    def step(carry, lp):
        # params may be f32 while activations are bf16; keep the carry dtype
        # stable so lax.scan typechecks (mixed-precision policy: activations
        # stay in the model compute dtype between layers).
        return fn(carry, lp, extra).astype(carry.dtype), None

    y, _ = jax.lax.scan(step, x, stacked_params)
    return y


def scan_layers_cache(body, x, stacked_params, stacked_cache, ctx, extra=None):
    """Like scan_layers but also threads per-layer cache: body returns
    (x, new_cache_l)."""
    fn = body
    if ctx.remat:
        fn = jax.checkpoint(body)

    def step(carry, xs):
        lp, lc = xs
        y, nc = fn(carry, lp, lc, extra)
        return y.astype(carry.dtype), nc

    y, new_cache = jax.lax.scan(step, x, (stacked_params, stacked_cache))
    return y, new_cache


def stack_layer_params(make_layer, rng, n: int):
    """Initialize ``n`` layers stacked along a leading dim (vmapped so a
    100-layer full config traces one layer, not 100)."""
    rngs = jax.random.split(rng, n)
    return jax.vmap(make_layer)(rngs)
