"""Model registry: uniform ``build_model(cfg)`` over every assigned arch.

Every family module exports the same functional interface:

* ``init_params(cfg, rng) -> params``
* ``param_specs(cfg, params, ctx) -> PartitionSpec pytree``
* ``forward(cfg, params, batch, ctx, *, window=None) -> logits``
* ``init_cache(cfg, batch, seq_len, *, window=None, dtype) -> cache``
* ``cache_specs(cfg, ctx) -> PartitionSpec pytree``
* ``decode_step(cfg, params, cache, tokens, pos, ctx, *, window=None)``

The registry adds:
* family -> module dispatch,
* ``make_batch`` / ``batch_specs`` covering modality stubs (audio frames,
  vision patches) per the assignment carve-out,
* ``input_specs`` ShapeDtypeStruct stand-ins for the dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import (moe, rglru, rwkv6, transformer, vision_llama,
                          whisper)
from repro.models.common import ParallelContext

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": moe,
    "hybrid": rglru,
    "ssm": rwkv6,
    "audio": whisper,
    "vlm": vision_llama,
}


@dataclasses.dataclass(frozen=True)
class Model:
    """Bound (cfg, family-module) pair with the uniform interface."""

    cfg: ModelConfig
    module: Any

    def init(self, rng) -> Any:
        """Raw fp init, then — for quantized configs — the in-memory plan
        compile (quantize + reorder/fold stages).  ``Model.init`` is
        therefore bit-exact with loading a ``DeploymentArtifact``
        ``prepare``d from the same seed: both run the identical
        ``plan/compiler.py`` pipeline on the identical raw stream."""
        raw = self.init_raw(rng)
        if self.cfg.quant.mode != "mlp":
            return raw
        from repro.plan import compiler  # lazy: compiler imports registry

        return compiler.compile_params(
            self.cfg, raw,
            rng=jax.random.fold_in(rng, compiler.PLAN_RNG_STREAM))

    def init_raw(self, rng) -> Any:
        """The family module's raw fp params (no quantization) — the plan
        compiler's input."""
        return self.module.init_params(self.cfg, rng)

    def param_specs(self, params, ctx: ParallelContext):
        return self.module.param_specs(self.cfg, params, ctx)

    def forward(self, params, batch, ctx: ParallelContext, *, window=None,
                aux=None):
        """``aux``: the deployment artifact's aux plans (e.g. precompiled
        attention V->O folds) — forwarded only to family modules that
        declare ``SUPPORTS_ATTN_VO``; other families ignore it (their
        attention has no fold integration yet)."""
        if aux is not None and self.supports_attn_vo:
            return self.module.forward(self.cfg, params, batch, ctx,
                                       window=window, aux=aux)
        return self.module.forward(self.cfg, params, batch, ctx,
                                   window=window)

    def init_cache(self, batch: int, seq_len: int, *, window=None,
                   dtype=jnp.bfloat16):
        return self.module.init_cache(self.cfg, batch, seq_len,
                                      window=window, dtype=dtype)

    def init_paged_cache(self, batch: int, n_pages: int, page_size: int, *,
                         bits=None, dtype=jnp.bfloat16):
        """Page-pool cache (``repro.cache``): families whose KV grows with
        the sequence export ``init_paged_cache``; recurrent families keep
        their O(1) dense state and never page."""
        if not self.supports_paged:
            raise ValueError(
                f"family {self.cfg.family!r} has no paged cache (its decode "
                "state is fixed-size per slot)")
        return self.module.init_paged_cache(self.cfg, batch, n_pages,
                                            page_size, bits=bits, dtype=dtype)

    @property
    def supports_paged(self) -> bool:
        return hasattr(self.module, "init_paged_cache")

    def cache_specs(self, ctx: ParallelContext):
        return self.module.cache_specs(self.cfg, ctx)

    @property
    def supports_attn_vo(self) -> bool:
        """True when the family's attention consumes precompiled V->O
        folds (``core/attention_fold``) from the artifact's aux tree."""
        return bool(getattr(self.module, "SUPPORTS_ATTN_VO", False))

    def decode_step(self, params, cache, tokens, pos, ctx: ParallelContext,
                    *, window=None, pages=None, aux=None):
        if aux is not None and self.supports_attn_vo:
            return self.module.decode_step(self.cfg, params, cache, tokens,
                                           pos, ctx, window=window,
                                           pages=pages, aux=aux)
        return self.module.decode_step(self.cfg, params, cache, tokens, pos,
                                       ctx, window=window, pages=pages)

    # ----- modality-stub batches -------------------------------------------

    def make_batch(self, rng, batch: int, seq_len: int,
                   *, with_labels: bool = False,
                   dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        rt, rf, rp = jax.random.split(rng, 3)
        out = {"tokens": jax.random.randint(rt, (batch, seq_len), 0,
                                            cfg.vocab_size)}
        if cfg.family == "audio":
            out["frames"] = jax.random.normal(
                rf, (batch, cfg.encoder_seq, cfg.d_model), dtype)
        if cfg.family == "vlm":
            out["patches"] = jax.random.normal(
                rp, (batch, cfg.vision_tokens, cfg.d_model), dtype)
        if with_labels:
            out["labels"] = jnp.roll(out["tokens"], -1, axis=1)
        return out

    def batch_shape_structs(self, batch: int, seq_len: int,
                            *, with_labels: bool = False,
                            dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        cfg = self.cfg
        out = {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), dtype)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct(
                (batch, cfg.vision_tokens, cfg.d_model), dtype)
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        return out

    def batch_specs(self, ctx: ParallelContext, *,
                    with_labels: bool = False) -> dict:
        cfg = self.cfg
        b = ctx.batch_spec
        out = {"tokens": P(b, None)}
        if cfg.family == "audio":
            out["frames"] = P(b, None, None)
        if cfg.family == "vlm":
            out["patches"] = P(b, None, None)
        if with_labels:
            out["labels"] = P(b, None)
        return out

    # ----- capability flags --------------------------------------------------

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def decode_window(self, seq_len: int) -> Optional[int]:
        """KV-cache window for a decode at ``seq_len``.

        Returns None for full-cache decode; a window size for the
        sliding-window (sub-quadratic) variant; raises if the shape is
        architecturally unsupported (whisper long_500k).
        """
        cfg = self.cfg
        if cfg.family in ("ssm",):
            return None  # O(1) state, no KV cache at all
        if cfg.family == "hybrid":
            return cfg.local_window
        if seq_len > 32_768:
            if cfg.family == "audio":
                raise ValueError(
                    "whisper decoder max positions 448; long_500k skipped "
                    "(DESIGN.md §5)")
            if cfg.attention_window is None:
                raise ValueError(
                    f"{cfg.arch_id}: long-context decode requires the "
                    "sliding-window variant (attention_window unset)")
            return cfg.attention_window
        return None


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise KeyError(f"unknown family {cfg.family!r}")
    return Model(cfg=cfg, module=_FAMILY_MODULES[cfg.family])
