"""Version compatibility shims for the jax API surface the repo uses.

``shard_map`` moved from ``jax.experimental.shard_map`` (jax <= 0.4.x,
``check_rep=``) to ``jax.shard_map`` (``check_vma=``).  Every explicit-
collective path in the repo goes through :func:`shard_map` below so both
API generations work.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(fn, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off (the schemes' outputs
    are intentionally partial-sum/sharded mid-body), on either jax API."""
    new_api = getattr(jax, "shard_map", None)
    if new_api is not None:
        return new_api(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as old_api

    return old_api(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
