"""Offline reordering plans — paper Algorithm 1 and the TP-aware fold.

Everything in this module runs *offline* (at model-preparation time): it
consumes raw fp weights, quantizes them, and emits a ``PlannedMLP`` /
``PlannedPair`` pytree in the exact layout each deployment scheme wants, so
the runtime schemes in ``schemes.py`` contain no layout logic.

Schemes (names used across the repo):

* ``naive-actorder`` — Eq. 3 deployment: original row order + unordered
  ``g_idx`` gather.  No activation permutes, no extra collectives, but poor
  metadata locality.
* ``exllama`` — Algorithm 1 layout (rows sorted by group).  This is the
  paper's **Naive Algorithm** (Algorithm 2) under TP: needs
  AllGather -> global permute by P2 -> chunk between the column-TP and
  row-TP layers.
* ``tp-aware`` — Algorithm 3: additionally permutes the *columns* of the
  column-TP weight(s) by P2 offline, eliminating the AllGather/permute/chunk.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as qz
from repro.core.quantization import QuantizedLinear

SCHEMES = ("naive-actorder", "exllama", "tp-aware")


def reorder(g_idx: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Paper Algorithm 1: P = argsort(g_idx); returns (P, g_idx[P])."""
    p = jnp.argsort(g_idx, stable=True).astype(jnp.int32)
    return p, g_idx[p]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlannedPair:
    """A column-TP -> row-TP quantized GEMM pair, deployment-ready.

    Covers the paper's MLP case (up -> down) and, beyond paper, any
    K1->N1->N2 pair (e.g. RWKV channel-mix K->V).  ``gate`` is the optional
    second column-TP matrix of a SwiGLU pair sharing the same P2 fold.
    """

    up: QuantizedLinear                    # (K1, N1) column-TP layer
    gate: Optional[QuantizedLinear]        # optional (K1, N1) SwiGLU gate
    down: QuantizedLinear                  # (N1, N2) row-TP layer
    p1_up: Optional[jax.Array]             # (K1,) X-gather perm (None: naive)
    p1_gate: Optional[jax.Array]
    p2: Optional[jax.Array]                # (N1,) down-rows perm
    scheme: str = dataclasses.field(metadata=dict(static=True))

    def forward(self, x: jax.Array, policy=None, mesh=None, *,
                axis: str = "model", batch_axes: tuple = (),
                activation: Optional[str] = None,
                pair_path: Optional[str] = None) -> jax.Array:
        """Canonical runtime entry point: run the pair under a deployment
        ``policy`` (``ExecutionPolicy``; None = defaults).

        ``mesh=None`` runs the single-device reference semantics; with a
        mesh, the paper's explicit-collective shard_map path runs over
        mesh axis ``axis``.  The *layout* is always ``self.scheme`` (the
        plan is baked into the weights offline); the policy supplies the
        kernel backend, dtypes, and the trailing collective —
        ``policy.collective.resolve(pair_path)``, so a per-layer
        ``CollectivePlan`` picks this pair's epilogue by its dotted param
        path (None: the plan default / the bare spec).
        """
        from repro.core import schemes

        if mesh is None:
            return schemes.pair_forward_reference(
                x, self, policy, activation=activation)
        return schemes.pair_forward_tp(
            x, self, mesh, policy, axis=axis, batch_axes=batch_axes,
            activation=activation, pair_path=pair_path)

    @property
    def k1(self) -> int:
        return self.up.k

    @property
    def n1(self) -> int:
        return self.up.n

    @property
    def n2(self) -> int:
        return self.down.n


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PairBundle:
    """Quantize-stage output for one GEMM pair — scheme-agnostic.

    Holds every layout the quantizer emits (naive + ordered + perms, via
    ``QuantResult``) so the *layout* stage can pick a deployment scheme
    later without re-quantizing.  This is the intermediate value the plan
    compiler (``plan/compiler.py``) threads between its quantize and
    reorder/fold stages; ``plan_pair`` composes both stages for callers
    that want a pair in one shot.
    """

    up: qz.QuantResult
    gate: Optional[qz.QuantResult]
    down: qz.QuantResult
    share_p1: bool = dataclasses.field(metadata=dict(static=True))


def quantize_pair(
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    w_gate: Optional[jax.Array] = None,
    group_size_up: int = 128,
    group_size_down: int = 128,
    act_order: bool = True,
    rng: Optional[jax.Array] = None,
    importance_up: Optional[jax.Array] = None,
    importance_down: Optional[jax.Array] = None,
    hessian_up: Optional[jax.Array] = None,
    hessian_down: Optional[jax.Array] = None,
    use_gptq: bool = False,
    share_p1: bool = True,
) -> PairBundle:
    """Compiler stage 1 for one pair: quantize, no layout decision yet.

    ``share_p1`` (beyond-paper): quantize the gate with the *up* matrix's
    processing order.  Importance is a property of the shared input
    channels, so one order serves both — the runtime then performs ONE
    ``X[:, P1]`` gather instead of two (see ``pair_forward_*``).
    """
    k1, n1 = w_up.shape
    n1_d, n2 = w_down.shape
    if n1_d != n1:
        raise ValueError(f"pair mismatch: up is {w_up.shape}, down is {w_down.shape}")
    if w_gate is not None and w_gate.shape != (k1, n1):
        raise ValueError(f"gate shape {w_gate.shape} != up shape {(k1, n1)}")

    rngs = (jax.random.split(rng, 3) if rng is not None else (None,) * 3)

    q_up = qz.quantize(w_up, group_size_up, act_order, importance=importance_up,
                       hessian=hessian_up, use_gptq=use_gptq, rng=rngs[0])
    q_down = qz.quantize(w_down, group_size_down, act_order,
                         importance=importance_down, hessian=hessian_down,
                         use_gptq=use_gptq, rng=rngs[1])
    q_gate = None
    if w_gate is not None:
        if share_p1:
            q_gate = qz.quantize(w_gate, group_size_up, act_order,
                                 hessian=hessian_up, use_gptq=use_gptq,
                                 proc_order=q_up.perm)
        else:
            q_gate = qz.quantize(w_gate, group_size_up, act_order,
                                 hessian=hessian_up, use_gptq=use_gptq,
                                 rng=rngs[2])

    return PairBundle(up=q_up, gate=q_gate, down=q_down, share_p1=share_p1)


def layout_pair(bundle: PairBundle, scheme: str = "tp-aware") -> PlannedPair:
    """Compiler stage 2 for one pair: pick the deployment layout.

    ``naive-actorder`` keeps the disk layout; ``exllama`` takes the
    Algorithm-1 sorted rows; ``tp-aware`` additionally folds the down
    projection's row sort P2 into the column-TP layer(s) offline
    (Algorithm 3), eliminating the runtime AllGather/permute/chunk.
    """
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}, expected one of {SCHEMES}")
    q_up, q_gate, q_down = bundle.up, bundle.gate, bundle.down

    if scheme == "naive-actorder":
        return PlannedPair(
            up=q_up.naive, gate=(q_gate.naive if q_gate else None),
            down=q_down.naive,
            p1_up=None, p1_gate=None, p2=None, scheme=scheme)

    p2 = q_down.perm                     # (N1,) — down's row sort (Alg. 1)
    up = q_up.ordered
    gate = q_gate.ordered if q_gate else None
    if scheme == "tp-aware":
        # Algorithm 3 fold: permute the column-TP layer's columns by P2 so
        # local Y1 shards come out pre-aligned with down's sorted rows.
        up = qz.permute_columns(up, p2)
        if gate is not None:
            gate = qz.permute_columns(gate, p2)

    return PlannedPair(
        up=up, gate=gate, down=q_down.ordered,
        p1_up=q_up.perm,
        # None marks "shares p1_up" — the runtime reuses the one gather
        p1_gate=(None if (q_gate is None or bundle.share_p1)
                 else q_gate.perm),
        p2=p2, scheme=scheme)


def plan_pair(
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    w_gate: Optional[jax.Array] = None,
    scheme: str = "tp-aware",
    group_size_up: int = 128,
    group_size_down: int = 128,
    act_order: bool = True,
    rng: Optional[jax.Array] = None,
    importance_up: Optional[jax.Array] = None,
    importance_down: Optional[jax.Array] = None,
    hessian_up: Optional[jax.Array] = None,
    hessian_down: Optional[jax.Array] = None,
    use_gptq: bool = False,
    share_p1: bool = True,
) -> PlannedPair:
    """Quantize + lay out a GEMM pair for the requested deployment scheme.

    Composition of the two compiler stages (``quantize_pair`` then
    ``layout_pair``) — the one-shot entry point for tests/benchmarks that
    plan a single pair outside the full ``plan/compiler.py`` pipeline.
    """
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}, expected one of {SCHEMES}")
    bundle = quantize_pair(
        w_up, w_down, w_gate=w_gate,
        group_size_up=group_size_up, group_size_down=group_size_down,
        act_order=act_order, rng=rng,
        importance_up=importance_up, importance_down=importance_down,
        hessian_up=hessian_up, hessian_down=hessian_down,
        use_gptq=use_gptq, share_p1=share_p1)
    return layout_pair(bundle, scheme)


# ---------------------------------------------------------------------------
# TP sharding of a plan (offline, host-side) — used by tests/benchmarks that
# drive shard_map with explicitly pre-sharded pytrees, and by the serving
# pipeline when materializing per-rank weights.
# ---------------------------------------------------------------------------

def shard_pair(pp: PlannedPair, tp: int) -> list[PlannedPair]:
    """Split a planned pair into ``tp`` per-rank plans.

    Column-TP layers split along N1 (qweight dim 1, metadata dim 1); the
    row-TP layer splits along N1 == its K (qweight dim 0 / 8, metadata groups
    dim 0).  Requires N1 % tp == 0 and (for the row layer) group-aligned
    shards: (N1 // tp) % group_size_down == 0.
    """
    n1 = pp.n1
    if n1 % tp:
        raise ValueError(f"N1={n1} not divisible by tp={tp}")
    shard = n1 // tp
    gs_d = pp.down.group_size
    if shard % qz.PACK:
        raise ValueError(
            f"row-TP shard {shard} must be a multiple of the int4 packing "
            f"factor {qz.PACK}")
    if shard % gs_d:
        raise ValueError(
            f"row-TP shard {shard} not aligned to down group_size {gs_d}; "
            f"re-plan with group_size_down={qz.choose_group_size(shard, gs_d)}")

    def col_slice(ql: QuantizedLinear, r: int) -> QuantizedLinear:
        sl = slice(r * shard, (r + 1) * shard)
        return dataclasses.replace(
            ql, qweight=ql.qweight[:, sl], scales=ql.scales[:, sl],
            zeros=ql.zeros[:, sl])

    def row_slice(ql: QuantizedLinear, r: int) -> QuantizedLinear:
        ksl = slice(r * shard // qz.PACK, (r + 1) * shard // qz.PACK)
        if ql.kind == "naive":
            # Unordered layout: a row shard touches arbitrary groups, so the
            # metadata table stays replicated and g_idx keeps global ids —
            # this *is* the locality problem the paper describes.
            return dataclasses.replace(
                ql, qweight=ql.qweight[ksl],
                g_idx=ql.g_idx[r * shard:(r + 1) * shard])
        gsl = slice(r * (shard // gs_d), (r + 1) * (shard // gs_d))
        return dataclasses.replace(
            ql, qweight=ql.qweight[ksl], scales=ql.scales[gsl],
            zeros=ql.zeros[gsl])

    out = []
    for r in range(tp):
        p2_local = pp.p2[r * shard:(r + 1) * shard] if pp.p2 is not None else None
        out.append(PlannedPair(
            up=col_slice(pp.up, r),
            gate=(col_slice(pp.gate, r) if pp.gate is not None else None),
            down=row_slice(pp.down, r),
            p1_up=pp.p1_up, p1_gate=pp.p1_gate,  # replicated
            p2=p2_local,
            scheme=pp.scheme))
    return out
