"""ExecutionPolicy — the paper's *a-priori deployment plan* as one object.

The paper's contribution is a plan decided before the first token: which
layout scheme the weights were prepared in (Algorithms 1-3), which kernel
executes the dequant-GEMM, what dtypes compute/accumulate in, and which
collective closes the row-TP layer.  The repo used to thread that plan
through the stack as loose kwargs duplicated at every call site; this
module makes it a single frozen, hashable record that flows from config
to kernel unchanged.

Both halves of the plan dispatch through registries:

* ``policy.backend`` — key into ``kernels/dispatch.py``
  (``(layout kind, backend) -> kernel``),
* ``policy.collective`` — a ``CollectiveSpec`` (one collective for every
  row-TP epilogue) or a ``CollectivePlan`` (per-layer selection: ordered
  ``{path glob: spec}`` + default), resolved by ``comm/dispatch.py``
  (``name -> strategy``); string shorthands like ``"psum"``,
  ``"cast:bfloat16"``, ``"quant-int8"`` or
  ``"per-layer:*.mlp=quant-int8,*=psum"`` are accepted and parsed via
  ``comm.parse_collective``.  Epilogues look their spec up with
  ``policy.collective.resolve(pair_path)`` — a bare spec resolves to
  itself for every path.

Construction paths:

* ``ExecutionPolicy.from_config(cfg)`` — the deployment plan recorded in a
  ``ModelConfig``/``QuantConfig`` (``backend="auto"`` resolves via the
  heuristic below).
* ``ExecutionPolicy.auto(scheme)`` — pick the fused Pallas kernel when the
  layout allows it (ordered layouts on a real TPU), fall back to the
  XLA-fused ``jnp`` path otherwise.
* ``ExecutionPolicy()`` — the historical defaults (tp-aware / jnp / f32 /
  psum), bit-identical to the original kwarg defaults.

Consumption: ``PlannedPair.forward(x, policy, mesh=...)`` is the canonical
runtime entry point.  See DESIGN.md §1 for the architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.comm.spec import (CollectivePlan, CollectiveSpec,
                             parse_collective)

__all__ = [
    "KernelTiling", "ExecutionPolicy", "DEFAULT_POLICY", "resolve_policy",
]


def _canon_dtype(dt):
    """Canonicalize a dtype-like to a hashable np.dtype (None passes)."""
    if dt is None:
        return None
    return jax.dtypes.canonicalize_dtype(dt)


@dataclasses.dataclass(frozen=True)
class KernelTiling:
    """Tile/lowering knobs for the fused Pallas kernels.

    ``block_k=None`` lets ``dequant_matmul.pick_block_k`` choose the
    largest group-aligned K tile; ``interpret=None`` auto-selects
    interpret mode off-TPU (this container) and compiled Mosaic on TPU.
    """

    block_m: int = 128
    block_n: int = 128
    block_k: Optional[int] = None
    interpret: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """The entire runtime execution contract for a quantized deployment.

    Frozen + hashable: safe as a jit static argument and inside
    ``shard_map`` closures.  ``scheme`` records the *offline* layout the
    weights were planned with (the runtime always trusts the plan pytree's
    own ``scheme`` field; a policy's copy exists so config-time code can
    carry the full plan in one object).  ``collective`` is the row-TP
    epilogue plan — a ``CollectiveSpec`` applied uniformly, or a
    ``CollectivePlan`` resolving a spec per pair path (string shorthands
    of either accepted); each epilogue dispatches its resolved spec
    through ``comm/dispatch.py``.
    """

    scheme: str = "tp-aware"
    backend: str = "jnp"            # key into kernels.dispatch registry
    compute_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32
    collective: Union[CollectiveSpec, CollectivePlan, str] = CollectiveSpec()
    tiling: KernelTiling = KernelTiling()
    # Decode-cache layout ("repro.cache.PageSpec"): dense per-slot rows,
    # or a shared page pool ("paged:16", "paged:16:int8", ...).  String
    # shorthands parse in __post_init__, mirroring ``collective``.
    kv: Any = None
    # Device-grid plan ("repro.dist.MeshPlan"): the DP×TP(×EP) grid the
    # deployment spans, as a frozen record or a "dp2xtp4" shorthand.
    # Recorded in the artifact manifest for provenance (serving on a
    # different grid with the same TP degree is allowed — validate only
    # pins the model-axis degree).
    mesh: Any = None

    def __post_init__(self):
        from repro.cache.spec import PageSpec
        from repro.core.reorder import SCHEMES
        from repro.dist.topology import MeshPlan
        if self.scheme not in SCHEMES:
            raise ValueError(
                f"unknown scheme {self.scheme!r}, expected one of {SCHEMES}")
        object.__setattr__(self, "collective",
                           parse_collective(self.collective))
        object.__setattr__(self, "compute_dtype",
                           _canon_dtype(self.compute_dtype))
        object.__setattr__(self, "accum_dtype",
                           _canon_dtype(self.accum_dtype))
        object.__setattr__(self, "kv", PageSpec.parse(self.kv))
        object.__setattr__(self, "mesh", MeshPlan.parse(self.mesh))

    # ---- builders ---------------------------------------------------------

    def with_(self, **kw) -> "ExecutionPolicy":
        return dataclasses.replace(self, **kw)

    def with_tiling(self, **kw) -> "ExecutionPolicy":
        return dataclasses.replace(
            self, tiling=dataclasses.replace(self.tiling, **kw))

    @classmethod
    def auto(cls, scheme: str = "tp-aware", *, on_tpu: Optional[bool] = None,
             **overrides) -> "ExecutionPolicy":
        """Heuristic plan: fused Pallas kernel when the layout allows.

        Ordered layouts (exllama / tp-aware) have the group-contiguous
        metadata the Pallas kernel's locality depends on; on TPU they get
        ``backend="pallas"``.  The naive g_idx layout and CPU hosts (where
        the kernel would run interpreted) fall back to ``jnp`` — XLA fuses
        the dequant into the GEMM epilogue there.
        """
        if on_tpu is None:
            try:
                on_tpu = jax.default_backend() == "tpu"
            except Exception:  # pragma: no cover
                on_tpu = False
        ordered = scheme != "naive-actorder"
        backend = "pallas" if (on_tpu and ordered) else "jnp"
        return cls(scheme=scheme, backend=backend, **overrides)

    @classmethod
    def from_config(cls, cfg) -> "ExecutionPolicy":
        """Build the deployment plan recorded in a ``ModelConfig`` (via its
        ``quant`` field) or a ``QuantConfig`` directly."""
        qc = getattr(cfg, "quant", cfg)
        dtypes = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                  "float16": jnp.float16, None: None}

        def lookup(field, name):
            try:
                return dtypes[name]
            except KeyError:
                raise ValueError(
                    f"unknown {field} {name!r}, expected one of "
                    f"{sorted(k for k in dtypes if k)}") from None

        compute = lookup("compute_dtype", qc.compute_dtype)
        collective = parse_collective(qc.collective)
        from repro.cache.spec import PageSpec
        kv = PageSpec(page_size=getattr(qc, "kv_page_size", None),
                      bits=getattr(qc, "kv_bits", None))
        if qc.backend == "auto":
            return cls.auto(qc.scheme, compute_dtype=compute,
                            collective=collective, kv=kv)
        return cls(scheme=qc.scheme, backend=qc.backend,
                   compute_dtype=compute, collective=collective, kv=kv)


DEFAULT_POLICY = ExecutionPolicy()


def resolve_policy(policy: Optional[ExecutionPolicy] = None) -> ExecutionPolicy:
    """``policy`` if given, else the historical defaults."""
    return policy if policy is not None else DEFAULT_POLICY
