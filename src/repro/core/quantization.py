"""GPTQ-style group quantization with activation-order support.

Implements the quantization substrate the paper builds on:

* group-wise asymmetric int4 quantization: every ``group_size`` input
  channels (rows of the ``(K, N)`` weight matrix) share one
  ``(scale, zero)`` pair per output channel,
* the ``act_order`` (``desc_act``) optimization (paper Eq. 3): rows are
  *processed* in importance order, so the row->group mapping becomes the
  unordered group-index array ``g_idx``,
* GPTQ error compensation (Frantar et al. 2023) with static groups — the
  variant AutoGPTQ uses when ``static_groups=True`` together with
  ``desc_act=True``, which is exactly the setting the paper's deployment
  story assumes (metadata computed up-front, rows re-orderable offline),
* int4 <-> int32 packing (8 nibbles per 32-bit word along K), the storage
  format consumed by the Pallas dequant kernels.

Layout convention used across the repo: ``W`` is ``(K, N)`` with ``K`` the
input-feature (reduction) dim — ``Y = X @ W``.  GPTQ groups run along K.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

QMAX = 15  # int4: quantized values live in [0, 15]
PACK = 8   # 8 int4 values per uint32 along K


def choose_group_size(k: int, preferred: int = 128) -> int:
    """Largest divisor of ``k`` that is ``<= preferred``.

    Under TP the per-shard K extent may not be divisible by the preferred
    group size (e.g. arctic's d_ff/16 = 304); the deployment plan then falls
    back to the largest group size that tiles the shard exactly.
    """
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    g = min(preferred, k)
    while k % g != 0:
        g -= 1
    return g


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedLinear:
    """A GPTQ-quantized ``(K, N)`` weight in deployment layout.

    ``kind`` (static):
      * ``"naive"``   — rows in original order; ``g_idx`` is the unordered
        Eq.-3 array and MUST be used to gather metadata (poor locality).
      * ``"ordered"`` — rows permuted by ``P = argsort(g_idx)`` (Algorithm 1);
        groups are contiguous: row ``i`` belongs to group ``i // group_size``;
        ``g_idx`` is None.  The caller must feed ``X[:, P]``.
    """

    qweight: jax.Array                  # (K // 8, N) uint32 packed int4
    scales: jax.Array                   # (G, N)
    zeros: jax.Array                    # (G, N)  (float zero-points)
    g_idx: Optional[jax.Array]          # (K,) int32 — only for kind="naive"
    group_size: int = dataclasses.field(metadata=dict(static=True))
    kind: str = dataclasses.field(metadata=dict(static=True))

    @property
    def k(self) -> int:
        return self.qweight.shape[0] * PACK

    @property
    def n(self) -> int:
        return self.qweight.shape[1]

    @property
    def num_groups(self) -> int:
        return self.scales.shape[0]


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack ``(K, N)`` int values in [0, 15] into ``(K//8, N)`` uint32."""
    k, n = q.shape
    if k % PACK != 0:
        raise ValueError(f"K={k} must be a multiple of {PACK}")
    q = q.astype(jnp.uint32).reshape(k // PACK, PACK, n)
    shifts = (jnp.arange(PACK, dtype=jnp.uint32) * 4)[None, :, None]
    return jnp.sum(q << shifts, axis=1, dtype=jnp.uint32)


def unpack_int4(qw: jax.Array) -> jax.Array:
    """Unpack ``(K//8, N)`` uint32 into ``(K, N)`` int32 values in [0, 15]."""
    k8, n = qw.shape
    shifts = (jnp.arange(PACK, dtype=jnp.uint32) * 4)[None, :, None]
    vals = (qw[:, None, :] >> shifts) & jnp.uint32(0xF)
    return vals.reshape(k8 * PACK, n).astype(jnp.int32)


# ---------------------------------------------------------------------------
# group metadata (static groups, computed up-front from W)
# ---------------------------------------------------------------------------

def _group_metadata(w_grouped: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Asymmetric min/max scales+zeros for ``(G, gs, N)`` grouped weights."""
    wmax = jnp.max(w_grouped, axis=1)
    wmin = jnp.min(w_grouped, axis=1)
    # guarantee 0 is representable and avoid zero scales
    wmax = jnp.maximum(wmax, 0.0)
    wmin = jnp.minimum(wmin, 0.0)
    scales = (wmax - wmin) / QMAX
    scales = jnp.where(scales <= 0, 1.0, scales)
    zeros = jnp.clip(jnp.round(-wmin / scales), 0, QMAX)
    return scales, zeros


def quantize_rtn(w: jax.Array, scales: jax.Array, zeros: jax.Array,
                 group_size: int) -> jax.Array:
    """Round-to-nearest int4 codes for ``(K, N)`` w given group metadata."""
    k, n = w.shape
    g = k // group_size
    wg = w.reshape(g, group_size, n)
    q = jnp.round(wg / scales[:, None, :] + zeros[:, None, :])
    return jnp.clip(q, 0, QMAX).astype(jnp.int32).reshape(k, n)


# ---------------------------------------------------------------------------
# GPTQ error compensation (static groups)
# ---------------------------------------------------------------------------

def _gptq_codes(w: jax.Array, scales: jax.Array, zeros: jax.Array,
                group_size: int, hinv_u: jax.Array) -> jax.Array:
    """Sequential GPTQ quantization with error feedback.

    ``w`` is already in *processing order* (rows pre-permuted by importance
    when act_order is on).  ``hinv_u`` is the upper-Cholesky factor of the
    inverse (permuted, damped) Hessian, as in Frantar et al.

    Returns int codes in processing order.
    """
    k, n = w.shape
    row_group = jnp.arange(k, dtype=jnp.int32) // group_size

    def body(w_work, i):
        g = row_group[i]
        s = scales[g]
        z = zeros[g]
        row = w_work[i]
        q = jnp.clip(jnp.round(row / s + z), 0, QMAX)
        dq = (q - z) * s
        d = hinv_u[i, i]
        err = (row - dq) / d
        # propagate error to not-yet-quantized rows (j > i)
        mask = (jnp.arange(k) > i).astype(w_work.dtype)[:, None]
        w_work = w_work - mask * hinv_u[i][:, None] * err[None, :]
        return w_work, q.astype(jnp.int32)

    _, q_rows = jax.lax.scan(body, w, jnp.arange(k))
    return q_rows


def cholesky_hinv_upper(h: jax.Array, damp_frac: float = 0.01) -> jax.Array:
    """Upper-triangular U with ``H^-1 = U^T U`` (GPTQ's ``Hinv``)."""
    k = h.shape[0]
    damp = damp_frac * jnp.mean(jnp.diag(h)) + 1e-8
    h = h + damp * jnp.eye(k, dtype=h.dtype)
    hinv = jnp.linalg.inv(h)
    # cholesky gives lower L with hinv = L L^T; the GPTQ factor is the
    # upper U = L^T (hinv = U^T U), so row i of U only touches cols j >= i.
    return jnp.linalg.cholesky(hinv).T


# ---------------------------------------------------------------------------
# top-level quantizer
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantResult:
    """Offline quantization artifact (the on-disk format + plan inputs).

    Registered as a pytree so the plan compiler's quantize stage can emit
    it under ``vmap`` (stacked layers/experts) and hand it to the layout
    stage as an intermediate ``PlanState`` value.
    """

    naive: QuantizedLinear          # disk layout: original row order + g_idx
    ordered: QuantizedLinear        # Algorithm-1 layout: rows sorted by group
    perm: jax.Array                 # P (K,) int32 — argsort(g_idx), stable
    g_idx: jax.Array                # (K,) unordered Eq.-3 group index array


def quantize(
    w: jax.Array,
    group_size: int = 128,
    act_order: bool = True,
    importance: Optional[jax.Array] = None,
    hessian: Optional[jax.Array] = None,
    use_gptq: bool = False,
    rng: Optional[jax.Array] = None,
    proc_order: Optional[jax.Array] = None,
) -> QuantResult:
    """Quantize ``W (K, N)`` and emit both deployment layouts.

    * ``importance``: per-input-channel importance (e.g. ``diag(H)``). With
      ``act_order=True`` rows are processed in descending-importance order;
      if None and ``rng`` given, a random permutation emulates an arbitrary
      reordering (paper Eq. 2); if both None, identity importance is used.
    * ``hessian``: (K, K) calibration Hessian for GPTQ error compensation.
    * ``use_gptq``: run the sequential error-feedback pass (slower, more
      accurate) rather than plain RTN.
    """
    k, n = w.shape
    if k % group_size != 0:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    w = w.astype(jnp.float32)

    if proc_order is not None:
        pass  # caller-supplied processing order (e.g. block-constrained)
    elif act_order:
        if importance is not None:
            proc_order = jnp.argsort(-importance, stable=True)
        elif hessian is not None:
            proc_order = jnp.argsort(-jnp.diag(hessian), stable=True)
        elif rng is not None:
            proc_order = jax.random.permutation(rng, k)
        else:
            proc_order = jnp.arange(k)
    else:
        proc_order = jnp.arange(k)
    proc_order = proc_order.astype(jnp.int32)

    # Eq. 3: row (original index) proc_order[j] is processed at position j,
    # hence belongs to group j // G.
    inv = jnp.zeros(k, dtype=jnp.int32).at[proc_order].set(
        jnp.arange(k, dtype=jnp.int32))
    g_idx = inv // group_size                      # unordered (original order)

    w_proc = w[proc_order]                         # processing order
    scales, zeros = _group_metadata(
        w_proc.reshape(k // group_size, group_size, n))

    if use_gptq:
        if hessian is None:
            hessian = jnp.eye(k, dtype=jnp.float32)
        hperm = hessian[proc_order][:, proc_order]
        hinv_u = cholesky_hinv_upper(hperm)
        q_proc = _gptq_codes(w_proc, scales, zeros, group_size, hinv_u)
    else:
        q_proc = quantize_rtn(w_proc, scales, zeros, group_size)

    # --- naive (disk) layout: original row order, unordered g_idx ----------
    q_orig = jnp.zeros_like(q_proc).at[proc_order].set(q_proc)
    naive = QuantizedLinear(
        qweight=pack_int4(q_orig), scales=scales, zeros=zeros,
        g_idx=g_idx, group_size=group_size, kind="naive")

    # --- Algorithm 1: P = argsort(g_idx), rows sorted by group -------------
    perm = jnp.argsort(g_idx, stable=True).astype(jnp.int32)
    # rows sorted by group == processing order up to stable intra-group order;
    # re-derive codes from q_orig to stay layout-exact.
    q_sorted = q_orig[perm]
    ordered = QuantizedLinear(
        qweight=pack_int4(q_sorted), scales=scales, zeros=zeros,
        g_idx=None, group_size=group_size, kind="ordered")

    return QuantResult(naive=naive, ordered=ordered, perm=perm, g_idx=g_idx)


# ---------------------------------------------------------------------------
# dequantization (pure-jnp reference paths; kernels/ has the TPU versions)
# ---------------------------------------------------------------------------

def dequantize(ql: QuantizedLinear, dtype=jnp.float32) -> jax.Array:
    """Materialize the fp weight ``(K, N)`` in the linear's own row layout."""
    q = unpack_int4(ql.qweight).astype(jnp.float32)
    if ql.kind == "ordered":
        g_idx = jnp.arange(ql.k, dtype=jnp.int32) // ql.group_size
    else:
        g_idx = ql.g_idx
    s = jnp.take(ql.scales, g_idx, axis=0)
    z = jnp.take(ql.zeros, g_idx, axis=0)
    return ((q - z) * s).astype(dtype)


def permute_columns(ql: QuantizedLinear, p: jax.Array) -> QuantizedLinear:
    """Offline column permutation (the TP-aware fold, paper Algorithm 3).

    Column permutations commute with K-grouped quantization: packing runs
    along K and metadata is per-(group, column), so permuting columns of
    ``qweight``/``scales``/``zeros`` jointly is exact.
    """
    return dataclasses.replace(
        ql,
        qweight=ql.qweight[:, p],
        scales=ql.scales[:, p],
        zeros=ql.zeros[:, p],
    )


def quant_error(ql: QuantizedLinear, w: jax.Array,
                perm: Optional[jax.Array] = None) -> jax.Array:
    """Mean |W - dq(q(W))| against the original-layout W (debug/tests)."""
    dq = dequantize(ql)
    if ql.kind == "ordered":
        assert perm is not None
        dq = jnp.zeros_like(dq).at[perm].set(dq)
    return jnp.mean(jnp.abs(w - dq))


def make_hessian(x_cal: jax.Array, damp: float = 0.0) -> jax.Array:
    """Calibration Hessian ``2 X^T X`` (GPTQ) from ``(B, K)`` activations."""
    x = x_cal.astype(jnp.float32)
    h = 2.0 * x.T @ x
    if damp:
        h = h + damp * jnp.eye(h.shape[0], dtype=h.dtype)
    return h
