"""Beyond-paper: head-block-constrained TP-aware folding for attention.

The paper (§2.2) restricts its fold to MLP pairs: "the sharding strategy
for Attention ... motivates the need for additional tricks".  This module
implements those tricks for the V-projection -> out-projection pair.

Why head blocks: the attention output channel ``c = (h, j)`` (query head
``h``, channel ``j``) is produced from V channel ``(h // g, j)`` of KV head
``h // g`` (GQA group size ``g = n_heads / n_kv_heads``).  Attention mixes
*tokens*, never channels, so any per-KV-head channel permutation ``π_kv``
commutes with attention exactly:

    attn(q, k, v[..., π]) == attn(q, k, v)[..., π]        (per head block)

Therefore an act_order permutation of W_o's rows is foldable into W_v's
columns **iff** it is (a) identical across the query heads of one KV group
and (b) confined to each head's ``head_dim`` block.  Under head-sharded TP
the blocks never cross rank boundaries, so — exactly like the paper's MLP
fold — the AllGather between V and out_proj disappears.

The cost of the constraint: act_order can only sort within blocks, so the
quantization-error win is smaller than unconstrained act_order — that
trade-off is measured in ``benchmarks/bench_attention_fold.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as qz
from repro.core.reorder import PlannedPair


def constrained_row_order(importance_o: jax.Array, *, n_heads: int,
                          n_kv_heads: int, head_dim: int
                          ) -> tuple[jax.Array, jax.Array]:
    """Block-constrained descending-importance order for W_o's rows.

    ``importance_o``: (n_heads * head_dim,) per-row importance.
    Returns (proc_order (K2,), pi (n_kv_heads, head_dim)) where
    ``proc_order[h*hd + j] = h*hd + pi[h // g, j]``.
    """
    g = n_heads // n_kv_heads
    imp = importance_o.reshape(n_kv_heads, g, head_dim)
    imp_kv = jnp.mean(imp, axis=1)                       # (kv, hd)
    pi = jnp.argsort(-imp_kv, axis=1).astype(jnp.int32)  # per-KV-head order
    base = (jnp.arange(n_heads, dtype=jnp.int32) * head_dim)[:, None]
    pi_per_q = pi[jnp.arange(n_heads) // g]              # (H, hd)
    return (base + pi_per_q).reshape(-1), pi


def plan_attention_vo(
    w_v: jax.Array,                 # (d_model, n_kv_heads * head_dim)
    w_o: jax.Array,                 # (n_heads * head_dim, d_model)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    group_size: int = 128,
    importance_o: Optional[jax.Array] = None,
    rng: Optional[jax.Array] = None,
) -> PlannedPair:
    """TP-aware plan for the V -> out_proj pair (scheme "tp-aware").

    The returned pair runs through ``schemes.pair_forward_tp`` unchanged —
    with attention applied between the two GEMMs by the caller (see
    ``attention_vo_reference``).  ``p2`` holds the block-constrained row
    order of W_o; W_v's columns are folded by the per-KV-head ``π``.
    """
    k2 = n_heads * head_dim
    if w_o.shape[0] != k2:
        raise ValueError(f"w_o rows {w_o.shape[0]} != H*hd {k2}")
    if head_dim % group_size and group_size % head_dim:
        raise ValueError(
            f"group_size {group_size} must tile head_dim {head_dim} so "
            "quant groups never cross foldable blocks")

    if importance_o is None:
        key = rng if rng is not None else jax.random.PRNGKey(0)
        importance_o = jax.random.uniform(key, (k2,))

    proc_order, pi = constrained_row_order(
        importance_o, n_heads=n_heads, n_kv_heads=n_kv_heads,
        head_dim=head_dim)

    gs_o = qz.choose_group_size(min(head_dim, k2), group_size)
    gs_v = qz.choose_group_size(w_v.shape[0], group_size)

    q_o = qz.quantize(w_o, gs_o, act_order=True, proc_order=proc_order)
    q_v = qz.quantize(w_v, gs_v, act_order=True, rng=rng)

    # fold: permute W_v's columns by π within each KV-head block, so the
    # attention output lands pre-aligned with W_o's sorted rows.
    kv_fold = (jnp.arange(n_kv_heads, dtype=jnp.int32)[:, None] * head_dim
               + pi).reshape(-1)
    v_folded = qz.permute_columns(q_v.ordered, kv_fold)

    return PlannedPair(
        up=v_folded, gate=None, down=q_o.ordered,
        p1_up=q_v.perm, p1_gate=None, p2=q_o.perm,
        scheme="tp-aware")


def attention_vo_reference(x, q_heads, attn_weights, pp: PlannedPair, *,
                           n_heads: int, n_kv_heads: int, head_dim: int,
                           policy=None) -> jax.Array:
    """Reference forward: X -> V -> attention-mix -> out_proj, folded plan.

    ``attn_weights``: (B, H, S, T) softmaxed scores (already computed from
    Q/K — V-channel permutations cannot affect them).  Used by the
    exactness tests; the serving path fuses this into the model's
    attention.  ``policy``: ``ExecutionPolicy`` selecting kernel/dtypes
    for the two quantized GEMMs (None = defaults).
    """
    from repro.core import schemes
    from repro.core.policy import resolve_policy

    policy = resolve_policy(policy)
    compute_dtype = policy.compute_dtype
    g = n_heads // n_kv_heads
    xin = jnp.take(x, pp.p1_up, axis=-1) if pp.p1_up is not None else x
    v = schemes.qmatmul(xin, pp.up, policy)
    b, t, _ = v.shape
    v = v.reshape(b, t, n_kv_heads, head_dim)
    # out[b, s, h] = sum_t attn[b, h, s, t] * v[b, t, h // g]
    out = jnp.einsum("bhst,bthd->bshd",
                     attn_weights.astype(compute_dtype),
                     jnp.repeat(v, g, axis=2))
    out = out.reshape(b, -1, n_heads * head_dim)
    return schemes.qmatmul(out, pp.down, policy)
