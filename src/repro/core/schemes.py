"""Runtime dequant-GEMM deployment schemes (paper Algorithms 2 and 3).

Three schemes, one arithmetic result (property-tested):

* ``naive-actorder`` — unordered Eq.-3 metadata gather.  TP: no extra
  collectives (chunks align naturally) but poor metadata locality.
* ``exllama`` — Algorithm-1 sorted layout.  TP (**paper's "Naive
  Algorithm"**, Algorithm 2): AllGather Y1 -> permute by P2 -> chunk.
* ``tp-aware`` — Algorithm 3: the P2 fold happened offline, so the TP path
  is GEMM -> GEMM -> trailing collective.  Strictly fewer collectives.

All functions are shape-polymorphic over leading batch dims: ``x`` is
``(..., K1)``.

Runtime knobs arrive as one ``ExecutionPolicy`` (``core/policy.py``);
``PlannedPair.forward(x, policy, mesh=...)`` is the canonical entry
point.  The kernel half of the plan dispatches through
``kernels/dispatch.py`` (``policy.backend``); the collective half through
``comm/dispatch.py`` (``policy.collective``) — no epilogue branching
happens here.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import dispatch as comm
from repro.core import compat
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.core.quantization import QuantizedLinear
from repro.core.reorder import PlannedPair


def _silu(x):
    return x * jax.nn.sigmoid(x)


ACTIVATIONS: dict[str, Callable] = {
    "silu": _silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
    "identity": lambda x: x,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def qmatmul(x: jax.Array, ql: QuantizedLinear,
            policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """``x @ dequantize(ql)`` via the policy-selected kernel.

    The kernel is resolved from ``(ql.kind, policy.backend)`` by the
    registry in ``kernels/dispatch.py`` — ``"jnp"`` materializes the fp
    weight (XLA fuses the dequant into the GEMM epilogue on TPU; also what
    the dry-run lowers so cost_analysis sees real FLOPs/bytes),
    ``"pallas"`` is the fused kernel (TPU hot path; interpret=True on
    CPU), ``"ref"`` the pure-jnp oracle.
    """
    policy = resolve_policy(policy)
    from repro.kernels import dispatch  # lazy: kernels optional at import

    return dispatch.qmatmul(x, ql, policy)


# ---------------------------------------------------------------------------
# single-device reference forwards
# ---------------------------------------------------------------------------

def pair_forward_reference(
    x: jax.Array,
    pp: PlannedPair,
    policy: Optional[ExecutionPolicy] = None,
    *,
    activation: Optional[str] = None,
) -> jax.Array:
    """Single-device forward of a planned pair; ground truth for TP tests."""
    policy = resolve_policy(policy)
    act = ACTIVATIONS[activation or "identity"]
    mm = functools.partial(qmatmul, policy=policy)

    if pp.scheme == "naive-actorder":
        y1 = mm(x, pp.up)
        if pp.gate is not None:
            y1 = act(mm(x, pp.gate)) * y1
        elif activation:
            y1 = act(y1)
        return mm(y1, pp.down)

    # exllama & tp-aware share the column-TP step: gather X by P1 first.
    xg = jnp.take(x, pp.p1_up, axis=-1)
    y1 = mm(xg, pp.up)
    if pp.gate is not None:
        # p1_gate None => gate shares p1_up (one gather, used twice)
        g = act(mm(xg if pp.p1_gate is None
                   else jnp.take(x, pp.p1_gate, axis=-1), pp.gate))
        y1 = g * y1
    elif activation:
        y1 = act(y1)
    if pp.scheme == "exllama":
        y1 = jnp.take(y1, pp.p2, axis=-1)   # runtime P2 permute (Alg. 2 l.3)
    # tp-aware: columns were folded by P2 offline — nothing to do.
    return mm(y1, pp.down)


# ---------------------------------------------------------------------------
# TP forwards (explicit collectives under shard_map)
# ---------------------------------------------------------------------------

def pair_pspecs(pp: PlannedPair, axis: str, x_batch_axes=()) -> PlannedPair:
    """PartitionSpec pytree matching ``pp`` for the model-TP axis ``axis``."""
    col = P(None, axis)

    def col_specs(ql: QuantizedLinear) -> QuantizedLinear:
        import dataclasses
        return dataclasses.replace(
            ql, qweight=col, scales=col, zeros=col,
            g_idx=(P(None) if ql.g_idx is not None else None))

    def row_specs(ql: QuantizedLinear) -> QuantizedLinear:
        import dataclasses
        if ql.kind == "naive":
            return dataclasses.replace(
                ql, qweight=P(axis, None), scales=P(None, None),
                zeros=P(None, None), g_idx=P(axis))
        return dataclasses.replace(
            ql, qweight=P(axis, None), scales=P(axis, None),
            zeros=P(axis, None), g_idx=None)

    import dataclasses
    return dataclasses.replace(
        pp,
        up=col_specs(pp.up),
        gate=(col_specs(pp.gate) if pp.gate is not None else None),
        down=row_specs(pp.down),
        p1_up=(P(None) if pp.p1_up is not None else None),
        p1_gate=(P(None) if pp.p1_gate is not None else None),
        p2=(P(axis) if pp.p2 is not None else None),
    )


_UNFUSABLE_WARNED: set = set()


def _warn_unfusable(pair_path, pp: PlannedPair, reason: str) -> None:
    """One-line, once-per-(site, reason) warning when a ':fused'
    collective spec cannot use the wire kernel here (wrong layout / tp=1
    / untileable K) — the dense GEMM + plain collective run instead of
    erroring.  The cache key is (site path, reason): under ``lax.scan``
    tracing (and re-traces for new shapes) the same site re-enters this
    function per trace, and the old shape-derived key let one site warn
    once per (K, N, tp) combination it was traced with."""
    import warnings

    key = (pair_path, reason)
    if key in _UNFUSABLE_WARNED:
        return
    _UNFUSABLE_WARNED.add(key)
    warnings.warn(
        f"collective spec is ':fused' but the wire kernel cannot serve "
        f"pair {pair_path!r} (scheme={pp.scheme}, down layout "
        f"{pp.down.kind!r}: {reason}); using the plain epilogue",
        stacklevel=3)


def _pair_local_forward(
    x: jax.Array,
    pp: PlannedPair,
    *,
    axis: str,
    activation: Optional[str],
    policy: ExecutionPolicy,
    pair_path: Optional[str] = None,
) -> jax.Array:
    """Per-rank body executed under shard_map.

    ``x`` is the local batch shard, replicated along ``axis``; the planned
    pair holds this rank's weight shards (column shards for up/gate, row
    shard for down, local P2 chunk for exllama).  The trailing collective
    is whatever ``policy.collective`` resolves to for this pair's dotted
    path (``pair_path``; a bare ``CollectiveSpec`` resolves to itself, a
    ``CollectivePlan`` does the per-layer glob lookup) — dispatched by the
    ``comm/dispatch.py`` registry, never branched here.
    """
    act = ACTIVATIONS[activation or "identity"]
    mm = functools.partial(qmatmul, policy=policy)

    if pp.scheme == "naive-actorder":
        # Original-order columns: local Y1 chunk already feeds the matching
        # down row-shard.  Comm: trailing collective only.  (Slow metadata
        # path.)
        y1 = mm(x, pp.up)
        if pp.gate is not None:
            y1 = act(mm(x, pp.gate)) * y1
        elif activation:
            y1 = act(y1)
    elif pp.scheme == "exllama":
        # Paper Algorithm 2 (the "Naive Algorithm" under TP).
        xg = jnp.take(x, pp.p1_up, axis=-1)
        y1 = mm(xg, pp.up)                                       # l.1 GEMM
        if pp.gate is not None:
            g = act(mm(xg if pp.p1_gate is None
                       else jnp.take(x, pp.p1_gate, axis=-1), pp.gate))
            y1 = g * y1
        elif activation:
            y1 = act(y1)
        y1_full = comm.all_gather_cols(y1, axis)                 # l.2
        y1 = jnp.take(y1_full, pp.p2, axis=-1)            # l.3+l.4 fused:
        # local P2 chunk both permutes and chunks the gathered tensor.
    elif pp.scheme == "tp-aware":
        # Paper Algorithm 3: offline fold removed the gather entirely.
        xg = jnp.take(x, pp.p1_up, axis=-1)
        y1 = mm(xg, pp.up)                                       # l.1 GEMM
        if pp.gate is not None:
            g = act(mm(xg if pp.p1_gate is None
                       else jnp.take(x, pp.p1_gate, axis=-1), pp.gate))
            y1 = g * y1
        elif activation:
            y1 = act(y1)
    else:
        raise ValueError(f"unknown scheme {pp.scheme!r}")

    # Down GEMM + trailing collective.  A ':fused' quant spec asks the
    # Pallas wire-epilogue kernel to emit ring phase 1's payload straight
    # from the accumulator tiles (DESIGN.md §10) — y_partial never lands
    # in HBM; otherwise the dense GEMM + plain collective run.  An
    # ':overlap' quant spec additionally pipelines the epilogue: the down
    # GEMM runs per row-microbatch with the decomposed ppermute ring of
    # one microbatch in flight across the next microbatch's GEMM
    # (dist/overlap.py, DESIGN.md §11) — bit-identical either way.
    spec = policy.collective.resolve(pair_path)
    use_wire = False
    if spec.fused:
        from repro.kernels import dispatch as kdispatch

        tp = comm.axis_size(axis)
        use_wire, reason = kdispatch.wire_support(pp.down, spec, tp)
        if not use_wire:
            _warn_unfusable(pair_path, pp, reason)
    if spec.overlap:
        from repro.dist import overlap as dist_overlap
        from repro.kernels import dispatch as kdispatch

        tp = comm.axis_size(axis)
        gemm_wire = (functools.partial(
            kdispatch.qmatmul_wire, ql=pp.down, policy=policy, spec=spec,
            tp=tp) if use_wire else None)
        return dist_overlap.pipelined_epilogue(
            y1, axis=axis, spec=spec,
            gemm=lambda y: mm(y, pp.down), gemm_wire=gemm_wire)
    if use_wire:
        from repro.kernels import dispatch as kdispatch

        tp = comm.axis_size(axis)
        wp = kdispatch.qmatmul_wire(y1, pp.down, policy, spec=spec, tp=tp)
        return comm.apply_wire(wp, axis, spec, policy)
    y2 = mm(y1, pp.down)                             # l.2 / l.5 down GEMM
    # l.6 / l.3: close the row-TP layer with the planned collective.
    return comm.apply(y2, axis, spec, policy)


def pair_forward_tp(
    x: jax.Array,
    pp: PlannedPair,
    mesh: jax.sharding.Mesh,
    policy: Optional[ExecutionPolicy] = None,
    *,
    axis: str = "model",
    batch_axes: tuple = (),
    activation: Optional[str] = None,
    pair_path: Optional[str] = None,
) -> jax.Array:
    """Tensor-parallel forward over mesh axis ``axis``.

    ``x``: (..., K1), sharded over ``batch_axes`` on its leading dim (if
    given), replicated along ``axis``.  Weights are consumed with the
    canonical TP sharding (see ``pair_pspecs``); under jit, GSPMD moves the
    globally-laid-out arrays into place, or callers pass pre-sharded arrays.
    ``pair_path`` names this pair in the deployment plan (dotted param
    path) so a per-layer ``CollectivePlan`` resolves the right epilogue.
    """
    policy = resolve_policy(policy)
    bspec = (batch_axes if batch_axes else None,) + (None,) * (x.ndim - 1)
    x_spec = P(*bspec)
    spec = policy.collective.resolve(pair_path)
    out_last = axis if comm.scatters_output(spec) else None
    out_spec = P(*((bspec[0],) + (None,) * (x.ndim - 2) + (out_last,)))

    fn = functools.partial(
        _pair_local_forward, axis=axis, activation=activation,
        policy=policy, pair_path=pair_path)
    return compat.shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, pair_pspecs(pp, axis)),
        out_specs=out_spec,
    )(x, pp)
