"""Dependency-light HTTP/SSE front end (stdlib only; DESIGN.md §8).

Routes:

* ``POST /v1/generate`` — JSON body ``{"prompt": [ids...]}`` or
  ``{"text": "..."}`` (byte-level stub tokenizer) plus optional
  ``max_new_tokens``, ``temperature``, ``top_p``, ``seed``.  Responds
  ``text/event-stream``: a ``start`` event, one ``token`` event per
  decoded token, and a terminal ``done`` (or ``cancelled``) event with
  usage stats.  ``429 Too Many Requests`` + ``Retry-After`` when the
  admission queue is full; ``503`` while draining.
* ``GET /v1/health`` — liveness + model identity.
* ``GET /v1/stats`` — queue depth, live slots, admission counters,
  TTFT / inter-token latency histograms (``loop.EngineLoop.stats``).

A client disconnect surfaces as a failed SSE write; the handler cancels
the request and the engine loop retires its slot at the next step
boundary — the slot is immediately free for the next admission.
"""

from __future__ import annotations

import json
import queue as stdlib_queue
import socket
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

import numpy as np

from repro.runtime.sampling import SamplingConfig
from repro.runtime.scheduler import Scheduler
from repro.runtime.serve import Engine
from repro.serving.loop import EngineLoop, Stream
from repro.serving.queue import QueueClosed, QueueFull

#: ceiling on waiting for the next token of one request before the
#: server gives up on it (prevents a wedged engine from pinning
#: handler threads forever)
TOKEN_TIMEOUT_S = 300.0


def tokenize_stub(text: str, vocab_size: int) -> np.ndarray:
    """Deterministic byte-level stand-in for a real tokenizer: one token
    per UTF-8 byte, folded into the model's vocab.  Good enough to
    exercise the serving path with ``{"text": ...}`` bodies; real
    deployments submit ``{"prompt": [ids...]}``."""
    data = np.frombuffer(text.encode("utf-8"), np.uint8)
    return (data.astype(np.int32) % vocab_size)


def _sse(event: str, payload: dict) -> bytes:
    return (f"event: {event}\ndata: {json.dumps(payload)}\n\n"
            ).encode("utf-8")


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server: "_HTTPServer"

    # quiet: the load generator would otherwise spam stderr per request
    def log_message(self, fmt, *args):
        pass

    def _json(self, code: int, payload: dict, headers: dict = ()):
        body = (json.dumps(payload, indent=2) + "\n").encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in dict(headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    # ------------------------------------------------------------------
    def do_GET(self):
        srv = self.server.serving
        if self.path == "/v1/health":
            self._json(200, {
                "status": "draining" if srv.loop.admission.closed
                else "ok",
                "arch": srv.engine.model.cfg.arch_id,
                "family": srv.engine.model.cfg.family,
                "collective": srv.engine.policy.collective.shorthand(),
                "kv": srv.engine.policy.kv.shorthand(),
            })
        elif self.path == "/v1/stats":
            self._json(200, srv.loop.stats())
        else:
            self._json(404, {"error": f"no route {self.path!r}"})

    # ------------------------------------------------------------------
    def do_POST(self):
        if self.path != "/v1/generate":
            self._json(404, {"error": f"no route {self.path!r}"})
            return
        srv = self.server.serving
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(length) or b"{}")
            prompt = self._prompt_ids(body, srv.engine.model.cfg.vocab_size)
            kwargs = self._sampling_kwargs(body)
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            self._json(400, {"error": str(e)})
            return

        try:
            stream = srv.loop.submit(prompt, **kwargs)
        except QueueFull as e:
            self._json(429, {"error": str(e),
                             "retry_after_s": e.retry_after},
                       headers={"Retry-After": f"{e.retry_after:g}"})
            return
        except QueueClosed as e:
            self._json(503, {"error": str(e)})
            return
        except ValueError as e:
            self._json(400, {"error": str(e)})
            return

        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        try:
            self.wfile.write(_sse("start", {"rid": stream.rid}))
            self.wfile.flush()
            self._pump(stream)
        except (BrokenPipeError, ConnectionResetError, socket.timeout,
                OSError):
            # client went away mid-stream: retire the slot at the next
            # step boundary so it frees for admission
            srv.loop.cancel(stream.rid)

    def _pump(self, stream: Stream):
        while True:
            try:
                kind, payload = stream.events.get(timeout=TOKEN_TIMEOUT_S)
            except stdlib_queue.Empty:
                self.server.serving.loop.cancel(stream.rid)
                self.wfile.write(_sse("error",
                                      {"error": "token timeout"}))
                self.wfile.flush()
                return
            if kind == "token":
                self.wfile.write(_sse("token", payload))
                self.wfile.flush()
            else:                      # "done" | "cancelled": terminal
                self.wfile.write(_sse(kind, {"usage": payload}))
                self.wfile.flush()
                return

    # ------------------------------------------------------------------
    @staticmethod
    def _prompt_ids(body: dict, vocab_size: int) -> np.ndarray:
        if "prompt" in body:
            ids = body["prompt"]
            if (not isinstance(ids, list) or not ids
                    or not all(isinstance(t, int) for t in ids)):
                raise ValueError("'prompt' must be a non-empty list of "
                                 "token ids")
            if max(ids) >= vocab_size or min(ids) < 0:
                raise ValueError(f"token id out of range [0, {vocab_size})")
            return np.asarray(ids, np.int32)
        if "text" in body:
            if not isinstance(body["text"], str) or not body["text"]:
                raise ValueError("'text' must be a non-empty string")
            return tokenize_stub(body["text"], vocab_size)
        raise ValueError("body needs 'prompt' (token ids) or 'text'")

    @staticmethod
    def _sampling_kwargs(body: dict) -> dict:
        out = {}
        max_new = body.get("max_new_tokens", 16)
        if not isinstance(max_new, int) or max_new < 1:
            raise ValueError("'max_new_tokens' must be a positive int")
        out["max_new_tokens"] = max_new
        for key, typ in (("temperature", (int, float)),
                         ("top_p", (int, float)), ("seed", int)):
            if body.get(key) is not None:
                if not isinstance(body[key], typ) or isinstance(
                        body[key], bool):
                    raise ValueError(f"'{key}' must be {typ[0].__name__}")
                out[key] = body[key]
        if "top_p" in out and not (0.0 < out["top_p"] <= 1.0):
            raise ValueError("'top_p' must be in (0, 1]")
        if "temperature" in out and out["temperature"] < 0.0:
            raise ValueError("'temperature' must be >= 0")
        return out


class _HTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    serving: "ServingServer"


class ServingServer:
    """The network front end: one ``EngineLoop`` + a threaded stdlib
    HTTP server.  ``port=0`` binds an ephemeral port (tests/bench)."""

    def __init__(self, engine: Engine, *, host: str = "127.0.0.1",
                 port: int = 0, max_batch: int = 4,
                 prompt_budget: int = 128,
                 scfg: SamplingConfig = SamplingConfig(),
                 seed: int = 0, queue_capacity: int = 64,
                 retry_after: float = 1.0, n_pages: Optional[int] = None,
                 cache_idle: float = 30.0):
        self.engine = engine
        self.loop = EngineLoop(
            Scheduler(engine, max_batch=max_batch,
                      prompt_budget=prompt_budget, scfg=scfg, seed=seed,
                      n_pages=n_pages),
            queue_capacity=queue_capacity, retry_after=retry_after,
            cache_idle=cache_idle)
        self._httpd = _HTTPServer((host, port), _Handler)
        self._httpd.serving = self
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> tuple[str, int]:
        return self._httpd.server_address[:2]

    @property
    def port(self) -> int:
        return self.address[1]

    def start(self) -> "ServingServer":
        """Run the engine loop + HTTP server on background threads."""
        self.loop.start()
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="http-server",
            daemon=True)
        self._thread.start()
        return self

    def serve_forever(self):
        """Foreground variant for the CLI (Ctrl-C -> graceful drain)."""
        self.loop.start()
        try:
            self._httpd.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            self.shutdown()

    def shutdown(self, *, drain: bool = True, timeout: float = 30.0):
        """Drain (default) or abort in-flight requests, then stop."""
        self.loop.shutdown(drain=drain, timeout=timeout)
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
