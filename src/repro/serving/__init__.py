"""Streaming HTTP/SSE serving front end over the continuous-batching
engine (DESIGN.md §8).

Layers, bottom up:

* ``repro.runtime.scheduler.Scheduler`` — the fixed-shape continuous
  decode program (one ``step()`` = one admission + decode step).
* ``loop.EngineLoop`` — a background thread that owns the scheduler,
  admits from the bounded queue only when a slot is free, fans decoded
  tokens out to per-request subscriber queues, and records TTFT /
  inter-token latency.
* ``queue.AdmissionQueue`` — bounded FIFO wait line with backpressure
  (``QueueFull`` -> HTTP 429 + ``Retry-After``) and drain-on-shutdown.
* ``server.ServingServer`` — the stdlib threaded HTTP server:
  ``POST /v1/generate`` (SSE token stream), ``GET /v1/health``,
  ``GET /v1/stats``.

No dependencies beyond the Python stdlib.
"""

from repro.serving.queue import AdmissionQueue, QueueClosed, QueueFull
from repro.serving.loop import EngineLoop, Stream
from repro.serving.server import ServingServer, tokenize_stub

__all__ = [
    "AdmissionQueue", "QueueClosed", "QueueFull",
    "EngineLoop", "Stream",
    "ServingServer", "tokenize_stub",
]
