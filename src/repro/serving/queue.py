"""Bounded FIFO admission queue with backpressure (DESIGN.md §8).

The queue is the server's *wait line*: the engine loop pops from it only
when a decode slot is free, so its depth is exactly the number of
admitted-but-not-yet-running requests.  When the line is full, ``offer``
raises ``QueueFull`` — the HTTP layer turns that into ``429 Too Many
Requests`` with a ``Retry-After`` hint — instead of letting latency grow
without bound.  ``close()`` starts the drain-on-shutdown path: no new
admissions (``QueueClosed`` -> 503), already-queued items still pop.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional


class QueueFull(Exception):
    """Wait line at capacity — retry after ``retry_after`` seconds."""

    def __init__(self, capacity: int, retry_after: float):
        super().__init__(f"admission queue full ({capacity})")
        self.capacity = capacity
        self.retry_after = retry_after


class QueueClosed(Exception):
    """Server is draining; no new admissions."""


class AdmissionQueue:
    """Thread-safe bounded FIFO of items carrying a ``.rid`` attribute."""

    def __init__(self, capacity: int = 64, *, retry_after: float = 1.0):
        self.capacity = capacity
        self.retry_after = retry_after
        self._items: deque = deque()
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._closed = False
        # counters (exported by /v1/stats)
        self.offered = 0
        self.rejected = 0
        self.cancelled = 0

    # ------------------------------------------------------------------
    def offer(self, item) -> None:
        """Enqueue or raise ``QueueFull`` / ``QueueClosed``."""
        with self._lock:
            if self._closed:
                raise QueueClosed("admission queue closed (draining)")
            if len(self._items) >= self.capacity:
                self.rejected += 1
                raise QueueFull(self.capacity, self.retry_after)
            self._items.append(item)
            self.offered += 1
            self._nonempty.notify()

    def pop(self, timeout: Optional[float] = None):
        """Dequeue the oldest item, or None on timeout / closed-empty."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while not self._items:
                if self._closed:
                    return None
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return None
                self._nonempty.wait(remaining)
            return self._items.popleft()

    def cancel(self, rid: int) -> bool:
        """Remove a still-queued item by rid (client gave up waiting)."""
        with self._lock:
            for item in self._items:
                if item.rid == rid:
                    self._items.remove(item)
                    self.cancelled += 1
                    return True
        return False

    def close(self) -> None:
        """Stop accepting; wake any blocked ``pop`` so drains finish."""
        with self._lock:
            self._closed = True
            self._nonempty.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def depth(self) -> int:
        return len(self._items)

    def __len__(self) -> int:
        return len(self._items)
