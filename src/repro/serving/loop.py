"""The background step loop that owns the Scheduler (DESIGN.md §8).

One thread drives the continuous-batching decode program; HTTP handler
threads never touch the engine.  The loop:

* admits from the bounded ``AdmissionQueue`` into the scheduler only
  when a decode slot is free (the admission queue is the wait line, the
  scheduler queue stays empty — ``/v1/stats`` queue depth is therefore
  the real backlog);
* runs ``Scheduler.step()`` and fans each emitted token out to the
  request's private subscriber queue (``Stream.events``);
* records per-request TTFT (submit -> first token) and inter-token
  latency, aggregated into the histograms ``/v1/stats`` reports;
* finalizes cancelled requests: a client disconnect flips
  ``Request.cancelled``; the scheduler retires the slot at the next
  step boundary and the loop emits the terminal ``cancelled`` event.

Request lifecycle:  submitted -> queued (wait line) -> running (slot)
-> {done | cancelled}.  Every terminal state posts exactly one
``("done", usage)`` or ``("cancelled", reason)`` event and sets
``Stream.finished``.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue as stdlib_queue
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.runtime.scheduler import Request, Scheduler
from repro.serving.queue import AdmissionQueue

_PERCENTILES = (50, 90, 99)
_RESERVOIR = 8192          # latency samples kept per histogram


@dataclasses.dataclass
class Stream:
    """Server-side handle for one in-flight request: the subscriber
    queue the HTTP handler reads, plus latency bookkeeping."""

    rid: int
    request: Request
    events: stdlib_queue.SimpleQueue = dataclasses.field(
        default_factory=stdlib_queue.SimpleQueue)
    submitted: float = dataclasses.field(default_factory=time.monotonic)
    started: Optional[float] = None       # admitted into the engine
    first_token: Optional[float] = None
    last_token: Optional[float] = None
    itl_ms: list = dataclasses.field(default_factory=list)
    finished: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    def usage(self, finish_reason: str) -> dict:
        now = time.monotonic()
        return {
            "prompt_tokens": int(self.request.prompt.size),
            "completion_tokens": len(self.request.output),
            "queue_ms": round(1e3 * ((self.started or now)
                                     - self.submitted), 3),
            "ttft_ms": (None if self.first_token is None else
                        round(1e3 * (self.first_token - self.submitted),
                              3)),
            "itl_ms_mean": (round(float(np.mean(self.itl_ms)), 3)
                            if self.itl_ms else None),
            "total_ms": round(1e3 * (now - self.submitted), 3),
            "finish_reason": finish_reason,
        }


def _histogram(samples) -> dict:
    if not samples:
        return {"count": 0}
    arr = np.asarray(samples, np.float64)
    out = {"count": int(arr.size),
           "mean": round(float(arr.mean()), 3)}
    for p in _PERCENTILES:
        out[f"p{p}"] = round(float(np.percentile(arr, p)), 3)
    # log2-spaced ms buckets, upper-edge labelled, zero buckets elided
    edges = [2.0 ** e for e in range(-2, 15)]   # 0.25ms .. 16384ms
    counts, _ = np.histogram(arr, bins=[0.0] + edges + [np.inf])
    labels = [f"le_{e:g}ms" for e in edges] + [f"gt_{edges[-1]:g}ms"]
    out["buckets"] = {lab: int(c)
                      for lab, c in zip(labels, counts) if c}
    return out


class EngineLoop:
    """Background thread owning a continuous-mode ``Scheduler``."""

    def __init__(self, scheduler: Scheduler, *, queue_capacity: int = 64,
                 retry_after: float = 1.0, idle_wait: float = 0.02,
                 cache_idle: float = 30.0):
        if not scheduler.engine.supports_continuous:
            raise ValueError(
                "HTTP serving needs token-granularity stepping; family "
                f"'{scheduler.engine.model.cfg.family}' only supports "
                "batch-drain scheduling (see Scheduler docstring)")
        self.scheduler = scheduler
        self.admission = AdmissionQueue(queue_capacity,
                                        retry_after=retry_after)
        self.idle_wait = idle_wait
        #: seconds of idle before the decode cache (dense rows or the
        #: whole page pool + prefix LRU) is released back to the
        #: allocator — a long-lived loop must not pin peak-batch cache
        #: memory between traffic bursts (the next request rebuilds it)
        self.cache_idle = cache_idle
        self._idle_since: Optional[float] = None
        #: head-of-line request a full page pool could not admit yet —
        #: held here (NOT in the scheduler queue) so the admission queue
        #: keeps backpressuring into 429s while it waits for pages
        self._pending: Optional[Stream] = None
        self._rids = itertools.count()
        self._streams: dict[int, Stream] = {}      # not yet finalized
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = False
        self._thread = threading.Thread(target=self._run,
                                        name="engine-loop", daemon=True)
        # counters + latency reservoirs (read by /v1/stats)
        self.started_at = time.monotonic()
        self.admitted = 0            # entered the engine
        self.completed = 0
        self.cancelled = 0
        self.tokens_out = 0
        self._ttft_ms: deque = deque(maxlen=_RESERVOIR)
        self._itl_ms: deque = deque(maxlen=_RESERVOIR)

    # ------------------------------------------------------------------
    # request API (called from HTTP handler threads)
    # ------------------------------------------------------------------

    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 16,
               temperature: Optional[float] = None,
               top_p: Optional[float] = None,
               seed: Optional[int] = None) -> Stream:
        """Enqueue a request; raises QueueFull/QueueClosed (backpressure)
        or ValueError (bad prompt/max_new vs the engine's budgets)."""
        sched = self.scheduler
        if prompt.size > sched.prompt_budget:
            raise ValueError(f"prompt {prompt.size} > budget "
                             f"{sched.prompt_budget}")
        if prompt.size + max_new_tokens > sched.engine.max_seq:
            raise ValueError(
                f"prompt {prompt.size} + max_new_tokens {max_new_tokens} "
                f"> engine max_seq {sched.engine.max_seq}")
        rid = next(self._rids)
        req = Request(rid=rid, prompt=prompt.astype(np.int32),
                      max_new_tokens=max_new_tokens,
                      temperature=temperature, top_p=top_p, seed=seed)
        stream = Stream(rid=rid, request=req)
        with self._lock:
            self._streams[rid] = stream
        try:
            self.admission.offer(stream)
        except Exception:
            with self._lock:
                self._streams.pop(rid, None)
            raise
        self._wake.set()
        return stream

    def cancel(self, rid: int) -> bool:
        """Client went away: drop a queued request immediately, or flag a
        running one so the scheduler retires its slot at the next step
        boundary (freeing it for admission)."""
        with self._lock:
            stream = self._streams.get(rid)
        if stream is None or stream.finished.is_set():
            return False
        stream.request.cancelled = True
        if self.admission.cancel(rid):
            # never reached the engine: finalize here, the loop owns
            # only requests it admitted
            self._finalize(stream, "cancelled")
        self._wake.set()
        return True

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "EngineLoop":
        self._thread.start()
        return self

    def shutdown(self, *, drain: bool = True, timeout: float = 30.0):
        """Stop the loop.  ``drain=True`` (graceful): close the wait
        line (new offers -> QueueClosed/503), let queued + running
        requests finish, then stop.  ``drain=False``: cancel everything
        in flight first."""
        self.admission.close()
        if not drain:
            with self._lock:
                rids = list(self._streams)
            for rid in rids:
                self.cancel(rid)
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if not self._streams:
                    break
            time.sleep(0.01)
        self._stop = True
        self._wake.set()
        self._thread.join(timeout=5.0)

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------

    def _free_capacity(self) -> int:
        sched = self.scheduler
        return sched.max_batch - sched.live_slots - len(sched.queue)

    def _run(self):
        sched = self.scheduler
        while not self._stop:
            # admit from the wait line only when a slot can take it (and,
            # in paged mode, only when the head's worst-case page
            # reservation fits — it stays parked in _pending, not the
            # scheduler queue, so /v1/stats queue depth remains the real
            # backlog and the bounded wait line 429s under pressure)
            while self._free_capacity() > 0:
                stream = self._pending or self.admission.pop(timeout=0)
                self._pending = None
                if stream is None:
                    break
                if stream.request.cancelled:
                    self._finalize(stream, "cancelled")
                    continue
                if not sched.can_admit(stream.request):
                    self._pending = stream
                    break
                stream.started = time.monotonic()
                sched.submit(stream.request)
                self.admitted += 1

            if not sched.has_work:
                now = time.monotonic()
                if self._idle_since is None:
                    self._idle_since = now
                elif (self._pending is None
                        and now - self._idle_since >= self.cache_idle):
                    if sched.release_cache():
                        self._idle_since = now
                self._wake.wait(self.idle_wait)
                self._wake.clear()
                continue
            self._idle_since = None

            for ev in sched.step():
                with self._lock:
                    stream = self._streams.get(ev.rid)
                if stream is None:        # already finalized (races are
                    continue              # benign: events are terminal)
                if ev.cancelled:
                    self._finalize(stream, "cancelled")
                    continue
                now = time.monotonic()
                if stream.first_token is None:
                    stream.first_token = now
                    self._ttft_ms.append(1e3 * (now - stream.submitted))
                else:
                    itl = 1e3 * (now - stream.last_token)
                    stream.itl_ms.append(itl)
                    self._itl_ms.append(itl)
                stream.last_token = now
                self.tokens_out += 1
                index = len(stream.request.output) - 1
                stream.events.put(("token", {"index": index,
                                             "token": ev.token}))
                if ev.final:
                    self._finalize(stream, "length")

    def _finalize(self, stream: Stream, reason: str):
        with self._lock:
            self._streams.pop(stream.rid, None)
        if reason == "cancelled":
            self.cancelled += 1
            stream.events.put(("cancelled", stream.usage(reason)))
        else:
            self.completed += 1
            stream.events.put(("done", stream.usage(reason)))
        stream.finished.set()

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        sched = self.scheduler
        with self._lock:
            in_flight = len(self._streams)
        uptime = time.monotonic() - self.started_at
        return {
            "uptime_s": round(uptime, 3),
            "queue": {
                "depth": self.admission.depth,
                "capacity": self.admission.capacity,
                "offered": self.admission.offered,
                "rejected": self.admission.rejected,
                "cancelled_queued": self.admission.cancelled,
                "closed": self.admission.closed,
            },
            "engine": {
                "live_slots": sched.live_slots,
                "max_batch": sched.max_batch,
                "prompt_budget": sched.prompt_budget,
                "max_seq": sched.engine.max_seq,
                "steps": sched._step_no,
            },
            "requests": {
                "admitted": self.admitted,
                "completed": self.completed,
                "cancelled": self.cancelled,
                "in_flight": in_flight,
            },
            "tokens": {
                "generated": self.tokens_out,
                "per_s": round(self.tokens_out / uptime, 3) if uptime
                else 0.0,
            },
            "latency_ms": {
                "ttft": _histogram(self._ttft_ms),
                "itl": _histogram(self._itl_ms),
            },
            "cache": sched.cache_stats(),
        }
