"""Collective autotuner — per-layer ``CollectivePlan`` as a compiler stage.

The repo used to apply ONE global ``ExecutionPolicy.collective`` to every
row-TP epilogue, but tolerance to wire compression varies sharply by
layer (down_proj vs attention O-proj vs MoE within-expert — Hansen-Palmus
et al. 2024; Dong et al. 2024 both mix bit-widths per layer to hold
quality while cutting wire bytes).  ``autotune_collectives`` makes that
decision offline, where the paper says the whole deployment plan lives:

for every pair site the quantize/layout stages planned (``pair_meta``),
it scores each candidate strategy with

* the strategy's analytic ``bytes_on_wire`` (ring cost model — the wire
  cost is shape-determined, no compilation needed), and
* a measured activation-error probe: the site's layer-0 pair is split
  into per-rank shards (``reorder.shard_pair``), calibration batches run
  through each rank's local forward (``pair_forward_reference`` computes
  exactly the partial sums a TP rank produces), and the wire is
  *simulated* with the same blockwise quantize/dequantize helpers the
  runtime strategies use — so the probe needs no mesh and runs on the
  prepare host,

then picks the CHEAPEST strategy whose relative error stays within
``budget`` and writes the resulting ``CollectivePlan`` (one fully
qualified path entry per site + a psum default) into
``PlanState.policy``.  The per-site scores land in
``PlanState.tuner_report`` and are serialized into the artifact manifest
so a served deployment can show why each layer got its collective.

Sites the tuner cannot shard for the target TP degree (non-divisible N1,
group-misaligned shards) keep the default — recorded as ``untunable`` in
the report, never silently dropped.

Two site-level refinements (DESIGN.md §10):

* When the winning spec is a quantized collective AND the site's down
  GEMM can run the fused wire-epilogue kernel
  (``kernels.dispatch.supports_wire``: ordered layout, tp > 1, tileable
  K), the compiled entry is marked ``:fused`` — the Pallas kernel emits
  ring phase 1's payload straight from the accumulator tiles.  The wire
  bytes and numerics are bit-identical to the unfused spec, so the
  score carries over; the report records ``fused: true``.
* Aux attention V->O folds (``cfg.quant.attn_tp_aware``) are probed as
  sites too (``kind: "attn_vo"`` in the report) now that the attention
  runtime consumes them; their entries join the plan under the fold's
  dotted path.  They are never marked fused — the attention forward
  closes its epilogue through GSPMD, not the explicit-collective path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm import dispatch as comm_dispatch
from repro.comm.spec import CollectivePlan, CollectiveSpec
from repro.core import reorder, schemes
from repro.core.quantization import choose_group_size

#: default max relative activation error a tuned collective may introduce
DEFAULT_BUDGET = 0.05

#: fold_in tag separating the tuner's calibration stream from the
#: quantize / attention-fold streams (same rng key, disjoint draws)
TUNE_RNG_STREAM = 0x54554E45  # "TUNE"


def candidate_specs() -> tuple[CollectiveSpec, ...]:
    """Tunable strategies: every registered full-output collective.

    ``none`` (partial sums) and scatter-output strategies are excluded —
    they change the epilogue's output contract, which is the caller's
    structural decision, not a quality/bytes trade-off.
    """
    out = []
    for name in comm_dispatch.strategies():
        if name == "none" or comm_dispatch.scatters_output(
                CollectiveSpec.parse(name)):
            continue
        out.append(CollectiveSpec.parse(name))
    return tuple(out)


def simulate_wire(partials, spec: CollectiveSpec) -> jax.Array:
    """Host-side simulation of ``comm.dispatch`` closing ``partials``.

    ``partials``: list of ``tp`` per-rank f32 partial sums (m, n).
    Reuses the dispatch module's own blockwise quantize/dequantize
    helpers, so the simulated wire loss is the runtime strategies' —
    phase 1 rounds every rank's contribution once, phase 2 rounds the
    re-quantized reduction once (the padded two-phase ring's numerics).
    """
    tp = len(partials)
    if spec.name in ("psum", "psum_scatter", "none") or tp == 1:
        return sum(partials[1:], partials[0])
    n = partials[0].shape[-1]
    if spec.name == "cast":
        # the all-reduce accumulates in the wire dtype on the wire
        acc = partials[0].astype(spec.wire_dtype)
        for p in partials[1:]:
            acc = (acc + p.astype(spec.wire_dtype)).astype(spec.wire_dtype)
        return acc.astype(partials[0].dtype)
    pad_to = tp * (8 if spec.bits == 4 else 1)
    bs = choose_group_size((n + (-n) % pad_to) // tp, spec.block_size)

    if spec.name == "quant-int8":
        def roundtrip(v):
            q, s = comm_dispatch._blockwise_quantize(v, bs)
            return comm_dispatch._blockwise_dequantize(q, s, bs)
    elif spec.name == "quant-int4":
        def roundtrip(v):
            q, s, z = comm_dispatch._blockwise_quantize_int4(v, bs)
            return comm_dispatch._blockwise_dequantize_int4(q, s, z, bs)
    else:
        raise ValueError(f"no wire simulation for collective {spec.name!r}")

    pad = (-n) % bs
    padded = [jnp.pad(p, [(0, 0)] * (p.ndim - 1) + [(0, pad)]) if pad else p
              for p in partials]
    red = sum(roundtrip(p) for p in padded)          # phase 1 per rank
    out = roundtrip(red)                             # phase 2 re-quantize
    return out[..., :n] if pad else out


def _site_pair(params, path: str, stacked):
    """The layer-0 ``PlannedPair`` at a dotted ``pair_meta`` path."""
    node = params
    for part in path.split("."):
        node = node[part]
    lead = len(stacked)
    if lead:
        node = jax.tree.map(lambda a: a[(0,) * lead], node)
    return node


def _site_attn_pair(plans):
    """The layer-0 V->O ``PlannedPair`` of a (possibly stacked) aux fold."""
    lead = plans.up.qweight.ndim - 2
    if lead:
        return jax.tree.map(lambda a: a[(0,) * lead], plans)
    return plans


def _probe_site(pp, tp: int, rng, calib_batch: int, candidates,
                activation: Optional[str]):
    """Score every candidate on one pair site; returns {shorthand: dict}."""
    from repro.kernels import dispatch as kdispatch

    shards = reorder.shard_pair(pp, tp)
    x = jax.random.normal(rng, (calib_batch, pp.k1), jnp.float32)
    partials = [
        jnp.asarray(schemes.pair_forward_reference(
            x, s, activation=activation), jnp.float32)
        for s in shards]
    exact = sum(partials[1:], partials[0])
    scale = float(jnp.max(jnp.abs(exact)))
    scores = {}
    for spec in candidates:
        sim = simulate_wire(partials, spec)
        err = float(jnp.max(jnp.abs(sim - exact))) / max(scale, 1e-30)
        # can the fused wire-epilogue kernel serve this site's down
        # GEMM? (per-rank shard geometry, so probe the shard) — keep the
        # verdict AND the reason: the manifest records this eligibility
        # provenance and repro.analysis cross-checks ':fused' marks
        # against it offline
        fusable, why = kdispatch.wire_support(shards[0].down, spec, tp)
        scores[spec.shorthand()] = {
            "spec": spec,
            "rel_err": err,
            # per-token wire bytes (batch-independent ranking)
            "bytes_per_token": spec.bytes_on_wire((1, pp.n2), tp),
            "fusable": fusable,
            "fuse_reason": why,
        }
    return scores


def autotune_collectives(state, mesh=None, *,
                         budget: float = DEFAULT_BUDGET,
                         calib_batch: int = 8,
                         candidates=None,
                         overlap: bool = False):
    """Compiler stage: choose a per-layer ``CollectivePlan`` for ``state``.

    ``mesh`` (optional) only supplies the TP degree when ``state.tp`` is
    unset — the probe itself is mesh-free (see ``simulate_wire``).
    Returns a new ``PlanState`` whose policy carries the tuned plan and
    whose ``tuner_report`` records every candidate's score per site.

    ``overlap=True`` (opt-in, the CLI's ``--overlap-collectives``)
    additionally marks each chosen *quantized* pair-site spec
    ``:overlap`` — the runtime then decomposes the two-phase ring into
    ppermute rotations pipelined against the next microbatch's
    dequant-GEMM (``dist/overlap.py``).  Bit-identical to the
    synchronous epilogue, so the tuned scores carry over unchanged;
    never applied to attn_vo sites (their epilogue closes through
    GSPMD, not the explicit-collective path).
    """
    tp = state.tp
    if tp is None and mesh is not None:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model")
    if not tp:
        raise ValueError(
            "autotune_collectives needs a target TP degree (PlanState.tp "
            "or a mesh with a 'model' axis)")
    tp = int(tp)
    default = CollectiveSpec(name="psum")
    if candidates is None:
        candidates = candidate_specs()

    # probe sites: every planned MLP pair, then (when the attention-fold
    # stage ran) every aux V->O fold — the attention runtime consumes
    # those pairs now, so their epilogues are collective sites too.
    sites = [(meta["path"], "pair",
              lambda meta=meta: _site_pair(state.params, meta["path"],
                                           meta["stacked"]),
              state.cfg.activation)
             for meta in state.pair_meta]
    sites += [(path, "attn_vo",
               lambda plans=plans: _site_attn_pair(plans),
               None)   # no activation between the V and O GEMMs
              for path, plans in sorted((state.attn_plans or {}).items())]

    entries, report = [], []
    for i, (path, kind, get_pair, activation) in enumerate(sites):
        rng = jax.random.fold_in(
            jax.random.fold_in(state.rng, TUNE_RNG_STREAM), i)
        if tp == 1:
            chosen, scores, status = default, {}, "tp=1 (no collective)"
        else:
            try:
                scores = _probe_site(get_pair(), tp, rng, calib_batch,
                                     candidates, activation)
                status = "tuned"
            except ValueError as e:   # non-divisible / group-misaligned
                scores, status = {}, f"untunable: {e}"
            ok = [v for v in scores.values() if v["rel_err"] <= budget]
            # nothing scored / nothing within budget -> the safe default
            chosen = (min(ok, key=lambda v: v["bytes_per_token"])["spec"]
                      if ok else default)
            if kind == "pair":
                # fuse the wire epilogue into the down GEMM where the
                # Pallas kernel can serve it: same wire bytes + numerics
                # (bit-identical payload), one less HBM round trip.
                win = scores.get(chosen.shorthand())
                if win is not None and win.get("fusable"):
                    chosen = chosen.with_(fused=True)
                    scores[chosen.shorthand()] = {**win, "spec": chosen}
                if overlap and chosen.name in ("quant-int8", "quant-int4"):
                    # same wire bytes + numerics, the ring just overlaps
                    # the next microbatch's GEMM (see docstring)
                    chosen = chosen.with_(overlap=True)
        entries.append((path, chosen))
        # eligibility provenance: WHY this site may (or may not) carry a
        # ':fused' wire epilogue, re-derivable offline from the shard on
        # disk — repro.analysis.manifest_lint cross-checks the mark
        # against this record and against kernels.dispatch.wire_support.
        if tp == 1:
            elig = {"fusable": False, "reason": status}
        elif kind != "pair":
            elig = {"fusable": False,
                    "reason": "attn_vo epilogue closes through GSPMD"}
        else:
            base = scores.get(chosen.shorthand()) or scores.get(
                chosen.with_(fused=False, overlap=False).shorthand())
            elig = ({"fusable": base["fusable"],
                     "reason": base["fuse_reason"]}
                    if base is not None
                    else {"fusable": False, "reason": status})
        report.append({
            "path": path, "kind": kind, "tp": tp, "budget": budget,
            "status": status, "chosen": chosen.shorthand(),
            "fused": chosen.fused, "overlap": chosen.overlap,
            "eligibility": elig,
            "candidates": {
                short: {"rel_err": v["rel_err"],
                        "bytes_per_token": v["bytes_per_token"]}
                for short, v in scores.items()},
        })

    plan = CollectivePlan(entries=tuple(entries), default=default)
    return dataclasses.replace(
        state, policy=state.policy.with_(collective=plan),
        tuner_report=tuple(report))
