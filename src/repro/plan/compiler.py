"""Staged offline plan compiler (the repo's "prepare" step).

The paper's premise is that reordering (Algorithm 1), the P2 fold
(Algorithm 3), and the TP collective schedule are all decided *before the
first token*.  This module is where that decision happens — once, offline
— as a pipeline of pure functions over a ``PlanState``:

1. ``stage_quantize``   — walk the raw fp pytree; every MLP weight dict
   (``{"w_up", "w_down"[, "w_gate"]}``, arbitrarily stacked over leading
   L / (L, E) dims) becomes a scheme-agnostic ``PairBundle`` (both
   layouts + perms, ``core/reorder.quantize_pair`` under nested vmap).
2. ``stage_layout``     — every bundle becomes a ``PlannedPair`` in the
   policy's deployment scheme (Algorithm-1 ordering; for ``tp-aware``
   additionally the offline P2 column fold).
3. ``stage_fold_attention`` — beyond-paper: when
   ``cfg.quant.attn_tp_aware`` is set, plan the V->out_proj pairs with
   the head-block-constrained fold (``core/attention_fold.py``) into the
   artifact's aux tree.
4. ``autotune_collectives`` (``plan/tuner.py``, opt-in) — score every
   registered full-output collective per pair site (analytic wire bytes
   + a measured activation-error probe on calibration batches) and write
   the chosen per-layer ``CollectivePlan`` into the policy.
5. ``stage_shard``      — pre-split the planned pytree into per-rank
   row/column shards for the target TP degree, driven by the model's own
   ``param_specs`` (any leaf whose spec names the model axis is sliced;
   non-divisible leaves stay replicated and are recorded as such).

``compile_params`` runs stages 1-2 in memory — this is what
``models/registry.Model.init`` calls, so building a quantized model IS
running the compiler (bit-exact with serving from an artifact ``prepare``d
from the same seed).  ``compile_plan`` runs all stages and wraps the
result in a serializable ``DeploymentArtifact``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import attention_fold, reorder
from repro.core.policy import ExecutionPolicy
from repro.core.quantization import choose_group_size
from repro.core.reorder import PairBundle, PlannedPair

#: fold_in tag separating the quantization rng stream from the init stream
#: (``Model.init`` and ``prepare`` must derive identical plan rngs from the
#: same seed for the artifact path to be bit-exact with the in-memory one).
PLAN_RNG_STREAM = 0x504C414E  # "PLAN"


@dataclasses.dataclass(frozen=True)
class PlanState:
    """The value threaded through the compiler stages (pure functions)."""

    cfg: ModelConfig
    policy: ExecutionPolicy
    params: Any                      # raw fp -> bundles -> planned pytree
    rng: jax.Array
    tp: Optional[int] = None         # target TP degree (None: no pre-shard)
    pair_meta: tuple = ()            # per-pair layout metadata (manifest)
    attn_plans: Any = None           # beyond-paper V->O folds (aux tree)
    rank_params: Optional[tuple] = None  # per-rank trees after stage_shard
    leaf_shards: Optional[dict] = None   # {leaf key: sliced dim | None}
    tuner_report: tuple = ()         # per-pair collective scores (manifest)


def _is_mlp_dict(node: Any) -> bool:
    return isinstance(node, dict) and "w_up" in node and "w_down" in node


def _walk_mlp(node: Any, fn, path: tuple = ()) -> Any:
    """Recursively rebuild ``node``, applying ``fn(mlp_dict, path)`` to
    every MLP weight dict."""
    if _is_mlp_dict(node):
        return fn(node, path)
    if isinstance(node, dict):
        return {k: _walk_mlp(v, fn, path + (k,)) for k, v in node.items()}
    return node


def _pair_group_sizes(cfg: ModelConfig, w_up, w_down) -> tuple[int, int]:
    """The deployment group sizes for one pair — identical to what the
    (deleted) init-time quantization chose: the row-TP layer's K (= ff)
    shards over up to ``tp_groups`` ranks, so its group size must tile the
    per-rank shard exactly (paper Sec 2.1: quantize once, deploy at any
    TP)."""
    d = w_up.shape[-2]
    ff = w_down.shape[-2]
    ff_shard = ff // cfg.quant.tp_groups if ff % cfg.quant.tp_groups == 0 \
        else ff
    return (choose_group_size(d, cfg.quant.group_size),
            choose_group_size(ff_shard, cfg.quant.group_size))


def _vmap_stacked(fn, lead: int):
    for _ in range(lead):
        fn = jax.vmap(fn)
    return fn


# ---------------------------------------------------------------------------
# stage 1: quantize
# ---------------------------------------------------------------------------

def stage_quantize(state: PlanState) -> PlanState:
    """Raw fp MLP dicts -> scheme-agnostic ``PairBundle``s (+ metadata)."""
    cfg = state.cfg
    counter = [0]
    meta = []

    def quantize_one(node: dict, path: tuple) -> PairBundle:
        counter[0] += 1
        sub = jax.random.fold_in(state.rng, counter[0])
        w_up, w_down = node["w_up"], node["w_down"]
        w_gate = node.get("w_gate")
        lead = w_up.ndim - 2
        gs_up, gs_down = _pair_group_sizes(cfg, w_up, w_down)

        def q_one(*args):
            if w_gate is None:
                wu, wd, r = args
                wg = None
            else:
                wu, wd, wg, r = args
            return reorder.quantize_pair(
                wu, wd, w_gate=wg, group_size_up=gs_up,
                group_size_down=gs_down, act_order=cfg.quant.act_order,
                rng=r)

        if lead == 0:
            rngs = sub
        else:
            nstack = 1
            for d in w_up.shape[:lead]:
                nstack *= d
            rngs = jax.random.split(sub, nstack).reshape(
                *w_up.shape[:lead], 2)
        args = (w_up, w_down, rngs) if w_gate is None else (
            w_up, w_down, w_gate, rngs)
        bundle = _vmap_stacked(q_one, lead)(*args)
        # dotted paths: the SAME string the runtime epilogues resolve
        # their per-layer collective by (models pass it to mlp_forward)
        meta.append({
            "path": ".".join(path), "stacked": list(w_up.shape[:lead]),
            "k1": int(w_up.shape[-2]), "n1": int(w_up.shape[-1]),
            "n2": int(w_down.shape[-1]), "gate": w_gate is not None,
            "group_size_up": gs_up, "group_size_down": gs_down,
        })
        return bundle

    params = _walk_mlp(state.params, quantize_one)
    return dataclasses.replace(state, params=params,
                               pair_meta=tuple(meta))


# ---------------------------------------------------------------------------
# stage 2: reorder / fold (layout)
# ---------------------------------------------------------------------------

def stage_layout(state: PlanState) -> PlanState:
    """``PairBundle``s -> ``PlannedPair``s in the policy's scheme."""
    scheme = state.policy.scheme

    def layout_one(node):
        if not isinstance(node, PairBundle):
            return node
        lead = node.up.naive.qweight.ndim - 2
        return _vmap_stacked(
            lambda b: reorder.layout_pair(b, scheme), lead)(node)

    params = jax.tree.map(layout_one, state.params,
                          is_leaf=lambda x: isinstance(x, PairBundle))
    meta = tuple(dict(m, scheme=scheme) for m in state.pair_meta)
    return dataclasses.replace(state, params=params, pair_meta=meta)


# ---------------------------------------------------------------------------
# stage 3: beyond-paper attention V->O fold
# ---------------------------------------------------------------------------

def _is_attn_dict(node: Any) -> bool:
    return isinstance(node, dict) and "wv" in node and "wo" in node


def stage_fold_attention(state: PlanState) -> PlanState:
    """Plan head-block-constrained V->O folds (``cfg.quant.attn_tp_aware``).

    The folded pairs land in ``state.attn_plans`` (mirroring the param
    paths) — serialized with the artifact so the attention runtime
    integration consumes precompiled plans instead of re-folding."""
    cfg = state.cfg
    if not cfg.quant.attn_tp_aware:
        return state
    from repro.models.common import head_grid

    kvp, _, hp = head_grid(cfg)
    hd = cfg.head_dim
    gs = choose_group_size(hd, cfg.quant.group_size)
    counter = [0]
    plans = {}

    def fold(node: Any, path: tuple = ()):
        if _is_attn_dict(node):
            counter[0] += 1
            # offset keeps the attention-fold stream disjoint from the MLP
            # quantize stage's fold_in counters
            sub = jax.random.fold_in(state.rng, 0x41545400 + counter[0])
            w_v, w_o = node["wv"], node["wo"]
            lead = w_v.ndim - 2
            nstack = 1
            for d in w_v.shape[:lead]:
                nstack *= d
            rngs = (sub if lead == 0 else
                    jax.random.split(sub, nstack).reshape(
                        *w_v.shape[:lead], 2))

            def fold_one(wv, wo, r):
                return attention_fold.plan_attention_vo(
                    wv, wo, n_heads=hp, n_kv_heads=kvp, head_dim=hd,
                    group_size=gs, rng=r)

            plans[".".join(path)] = _vmap_stacked(fold_one, lead)(
                w_v, w_o, rngs)
            return
        if isinstance(node, dict):
            for k, v in node.items():
                fold(v, path + (k,))

    fold(state.params)
    return dataclasses.replace(state, attn_plans=plans or None)


# ---------------------------------------------------------------------------
# stage 4: TP pre-shard
# ---------------------------------------------------------------------------

def _model_axis_dim(spec, axis: str) -> Optional[int]:
    """Position of ``axis`` in a PartitionSpec (None: not sharded here)."""
    if spec is None:
        return None
    for i, entry in enumerate(spec):
        if entry == axis:
            return i
        if isinstance(entry, (tuple, list)) and axis in entry:
            return i
    return None


@dataclasses.dataclass(frozen=True)
class _PlanContext:
    """Duck-typed ``ParallelContext`` stand-in for spec queries at prepare
    time: no mesh exists, but ``axis_size(model)`` must report the target
    TP degree so specs (e.g. vocab-dim embedding sharding) match what the
    serving mesh will decide."""

    tp: int
    model_axis: str = "model"
    batch_axes: tuple = ("data",)
    mesh: Any = None

    def axis_size(self, name: str) -> int:
        return self.tp if name == self.model_axis else 1

    @property
    def batch_spec(self):
        return self.batch_axes if self.batch_axes else None

    @property
    def ep_axis(self):
        return self.batch_axes[-1] if self.batch_axes else None


def shard_params(cfg: ModelConfig, params: Any, tp: int,
                 axis: str = "model") -> tuple[list, dict]:
    """Pre-split a planned pytree into ``tp`` per-rank trees.

    Sharding is driven by the model's own ``param_specs``: any leaf whose
    spec names ``axis`` is sliced into ``tp`` equal parts along that dim
    (column-TP layers along N1, the row-TP layer along its packed K and
    metadata groups, P2 into local chunks — exactly the layout
    ``core/reorder.shard_pair`` produces for a single pair); leaves whose
    sharded dim does not divide ``tp`` stay replicated and are recorded so
    the loader reassembles faithfully.  Returns ``(rank_trees,
    {leaf key: sliced dim | None})``.
    """
    from repro.models.registry import build_model
    from repro.train import checkpoint

    model = build_model(cfg)
    specs = model.param_specs(params, _PlanContext(tp=tp, model_axis=axis))

    flat_p = checkpoint.flatten_keys(params)
    from jax.sharding import PartitionSpec as P
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    if len(spec_leaves) != len(flat_p):
        raise ValueError(
            f"param_specs tree ({len(spec_leaves)} leaves) does not match "
            f"params ({len(flat_p)} leaves) for {cfg.arch_id}; cannot "
            "pre-shard this model")

    leaf_shards: dict[str, Optional[int]] = {}
    sliced: dict[str, list] = {}
    for (key, leaf), spec in zip(flat_p.items(), spec_leaves):
        dim = _model_axis_dim(spec, axis)
        if dim is not None and leaf.shape[dim] % tp == 0 \
                and leaf.shape[dim] >= tp:
            n = leaf.shape[dim] // tp
            parts = [jax.lax.slice_in_dim(leaf, r * n, (r + 1) * n, axis=dim)
                     for r in range(tp)]
            leaf_shards[key] = dim
        else:
            parts = [leaf] * tp
            leaf_shards[key] = None
        sliced[key] = parts

    treedef = jax.tree_util.tree_structure(params)
    keys = list(flat_p)
    rank_trees = [
        jax.tree_util.tree_unflatten(treedef, [sliced[k][r] for k in keys])
        for r in range(tp)
    ]
    return rank_trees, leaf_shards


def stage_shard(state: PlanState) -> PlanState:
    if state.tp is None:
        return state
    rank_trees, leaf_shards = shard_params(state.cfg, state.params,
                                           state.tp)
    return dataclasses.replace(state, rank_params=tuple(rank_trees),
                               leaf_shards=leaf_shards)


# ---------------------------------------------------------------------------
# pipeline entry points
# ---------------------------------------------------------------------------

STAGES = (stage_quantize, stage_layout, stage_fold_attention, stage_shard)


def run_stages(state: PlanState, stages=STAGES) -> PlanState:
    for stage in stages:
        state = stage(state)
    return state


def compile_params(cfg: ModelConfig, raw_params: Any, *,
                   rng: Optional[jax.Array] = None,
                   policy: Optional[ExecutionPolicy] = None,
                   scheme: Optional[str] = None) -> Any:
    """In-memory compile: raw fp params -> planned pytree (stages 1-2).

    This is the single quantize/reorder call site model construction goes
    through (``Model.init``) and what ``quant/gptq.quantize_model`` wraps
    for trained checkpoints — and it is bit-exact with serving from an
    artifact ``prepare``d with the same config/policy/rng.
    """
    policy = policy if policy is not None else ExecutionPolicy.from_config(cfg)
    if scheme is not None:
        policy = policy.with_(scheme=scheme)
    state = PlanState(
        cfg=cfg, policy=policy, params=raw_params,
        rng=rng if rng is not None else jax.random.PRNGKey(0))
    return run_stages(state, (stage_quantize, stage_layout)).params


def compile_plan(cfg: ModelConfig, raw_params: Any, *, tp: int,
                 rng: Optional[jax.Array] = None,
                 policy: Optional[ExecutionPolicy] = None,
                 seed: Optional[int] = None,
                 extra_manifest: Optional[dict] = None,
                 autotune: bool = False,
                 tune_budget: Optional[float] = None,
                 tune_overlap: bool = False):
    """Full offline compile: raw fp params -> ``DeploymentArtifact``.

    Runs every stage (quantize, layout, attention fold, optional
    collective autotune, TP pre-shard) and freezes the result with its
    manifest.  ``autotune=True`` inserts ``plan/tuner.py``'s
    ``autotune_collectives`` (max rel-error ``tune_budget``; tuner
    default when None) so the artifact carries a per-layer
    ``CollectivePlan`` instead of one global collective.
    ``tune_overlap=True`` marks the tuner's quantized pair choices
    ``:overlap`` (decomposed compute-overlapped ring, DESIGN.md §11).
    ``seed`` is provenance only (recorded so a served artifact can name
    the init stream it came from).
    """
    from repro.plan.artifact import DeploymentArtifact

    policy = policy if policy is not None else ExecutionPolicy.from_config(cfg)
    state = PlanState(
        cfg=cfg, policy=policy, params=raw_params, tp=int(tp),
        rng=rng if rng is not None else jax.random.PRNGKey(0))
    stages = [stage_quantize, stage_layout, stage_fold_attention]
    if autotune:
        from repro.plan import tuner

        kw = {} if tune_budget is None else {"budget": tune_budget}
        kw["overlap"] = tune_overlap
        stages.append(lambda s: tuner.autotune_collectives(s, **kw))
    stages.append(stage_shard)
    state = run_stages(state, tuple(stages))
    return DeploymentArtifact.from_state(state, seed=seed,
                                         extra=extra_manifest)


def prepare(cfg: ModelConfig, *, tp: int, seed: int = 0,
            policy: Optional[ExecutionPolicy] = None,
            extra_manifest: Optional[dict] = None,
            autotune: bool = False,
            tune_budget: Optional[float] = None,
            tune_overlap: bool = False):
    """Seed -> artifact, the canonical prepare recipe.

    Derives the raw init and the plan rng exactly the way ``Model.init``
    does (``init_raw(key)`` + ``fold_in(key, PLAN_RNG_STREAM)``) — this
    is THE definition of "same seed" in the bit-exactness guarantee, so
    every prepare caller (CLI, examples, tests) must go through here.
    """
    from repro.models.registry import build_model

    key = jax.random.PRNGKey(seed)
    raw = build_model(cfg).init_raw(key)
    return compile_plan(
        cfg, raw, tp=tp, rng=jax.random.fold_in(key, PLAN_RNG_STREAM),
        policy=policy, seed=seed, extra_manifest=extra_manifest,
        autotune=autotune, tune_budget=tune_budget,
        tune_overlap=tune_overlap)
