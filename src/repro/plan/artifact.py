"""DeploymentArtifact — the frozen, serialized output of the plan compiler.

One directory per deployment:

* ``manifest.json`` — everything needed to validate a load: format
  version, arch id + config hash, the full ``ExecutionPolicy`` (scheme,
  backend, dtypes, collective shorthand — for a per-layer
  ``CollectivePlan`` the full ``per-layer:`` form, echoed structurally
  under ``collective_plan`` and, when the autotuner chose it, scored
  per site under ``collective_tuner``), the target TP degree, per-pair
  layout metadata from the compiler stages, and the per-leaf shard map
  (which dim of each checkpoint leaf was pre-split).
* ``rank_NN.npz`` — per-rank planned pytrees (packed uint32 weights,
  perms, scales, static scheme fields) via the schema-embedding
  ``train/checkpoint.py`` format.
* ``aux.npz`` — optional beyond-paper extras (attention V->O folds).

Loading NEVER re-runs GPTQ or the layout planner; ``validate`` refuses a
mismatched config, policy, or mesh degree, so an artifact can't silently
serve under a plan it wasn't compiled for.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.comm.spec import CollectivePlan
from repro.core.policy import ExecutionPolicy

FORMAT_VERSION = 1
MANIFEST = "manifest.json"


class PlanMismatchError(ValueError):
    """A deployment artifact was asked to serve under the wrong plan."""


def config_hash(cfg) -> str:
    """Stable content hash of a ``ModelConfig`` (nested dataclasses)."""
    blob = repr(sorted(dataclasses.asdict(cfg).items()))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def policy_fields(policy: ExecutionPolicy) -> dict:
    """The manifest's view of an ``ExecutionPolicy`` (strings only).

    ``kv`` and ``mesh`` are recorded for provenance (so a served stats
    endpoint and the artifact agree on what was prepared) but excluded
    from ``validate``'s comparison: the cache layout is a pure runtime
    decision, and the device grid may differ per deployment as long as
    the model-axis degree matches the shards (which ``validate``'s
    ``tp`` check pins) — an artifact prepared dp1xtp2 serves dp4xtp2.
    """
    return {
        "scheme": policy.scheme,
        "backend": policy.backend,
        "compute_dtype": jnp.dtype(policy.compute_dtype).name,
        "accum_dtype": jnp.dtype(policy.accum_dtype).name,
        "collective": policy.collective.shorthand(),
        "kv": policy.kv.shorthand(),
        "mesh": policy.mesh.shorthand(),
    }


@dataclasses.dataclass(frozen=True)
class DeploymentArtifact:
    """Frozen (manifest, per-rank planned pytrees, aux) triple.

    Two load shapes: ``load`` holds every rank's host pytree in
    ``rank_params`` (single-process serving; ``params`` reassembles);
    ``load_for_mesh`` holds NO host copies — ``global_params`` is the
    already-device-sharded tree assembled from only this process's rank
    files (``dist/loader.py``), and ``load_stats`` is the byte ledger
    proving which files were read."""

    manifest: dict
    rank_params: tuple = ()          # tp per-rank planned pytrees
    aux: Optional[dict] = None       # e.g. {"attn_plans": {path: pairs}}
    global_params: Any = None        # mesh-sharded tree (load_for_mesh)
    load_stats: Any = None           # dist.loader.RankLoadStats

    # ---- construction -----------------------------------------------------

    @classmethod
    def from_state(cls, state, *, seed: Optional[int] = None,
                   extra: Optional[dict] = None) -> "DeploymentArtifact":
        """Freeze a fully-run ``PlanState`` (see ``compiler.run_stages``).

        ``extra``: caller-provenance manifest fields (e.g. the CLI's
        ``smoke`` flag) — merged in, never overriding the plan fields."""
        if state.rank_params is None:
            raise ValueError(
                "PlanState has no rank shards; run stage_shard (tp=...) "
                "before freezing an artifact")
        manifest = {
            "format_version": FORMAT_VERSION,
            "arch_id": state.cfg.arch_id,
            "config_hash": config_hash(state.cfg),
            "quant": dataclasses.asdict(state.cfg.quant),
            "policy": policy_fields(state.policy),
            "tp": state.tp,
            "seed": seed,
            "pairs": list(state.pair_meta),
            "leaf_shards": dict(state.leaf_shards),
        }
        coll = state.policy.collective
        if isinstance(coll, CollectivePlan):
            # structural echo of the per-layer plan (the policy field
            # above already carries the authoritative shorthand)
            manifest["collective_plan"] = {
                "entries": [[pat, spec.shorthand()]
                            for pat, spec in coll.entries],
                "default": coll.default.shorthand(),
            }
        if getattr(state, "tuner_report", ()):
            manifest["collective_tuner"] = list(state.tuner_report)
        if extra:
            manifest = {**extra, **manifest}
        aux = ({"attn_plans": state.attn_plans}
               if state.attn_plans is not None else None)
        return cls(manifest=manifest, rank_params=tuple(state.rank_params),
                   aux=aux)

    # ---- accessors --------------------------------------------------------

    @property
    def tp(self) -> int:
        return int(self.manifest["tp"])

    @property
    def scheme(self) -> str:
        return self.manifest["policy"]["scheme"]

    def policy(self) -> ExecutionPolicy:
        p = self.manifest["policy"]
        return ExecutionPolicy(
            scheme=p["scheme"], backend=p["backend"],
            compute_dtype=p["compute_dtype"], accum_dtype=p["accum_dtype"],
            collective=p["collective"], kv=p.get("kv", "dense"),
            mesh=p.get("mesh"))

    def rank_tree(self, r: int):
        return self.rank_params[r]

    def params(self):
        """Reassemble the global planned pytree (what single-program
        GSPMD/shard_map serving consumes; per-rank serving uses
        ``rank_tree``).  Slicing then concatenating is the identity, so
        this is bit-exact with the in-memory compile."""
        from repro.train import checkpoint

        if self.global_params is not None:
            # load_for_mesh already assembled the device-sharded tree
            return self.global_params
        if not self.rank_params:
            raise ValueError(
                "artifact holds no rank pytrees (loaded per-rank for a "
                "mesh without assembled params?) — use load_for_mesh's "
                "global_params or reload with DeploymentArtifact.load")
        shards = self.manifest["leaf_shards"]
        flats = [checkpoint.flatten_keys(t) for t in self.rank_params]
        keys = list(flats[0])
        leaves = []
        for key in keys:
            dim = shards.get(key)
            if dim is None:
                leaves.append(flats[0][key])
            else:
                leaves.append(jnp.concatenate(
                    [f[key] for f in flats], axis=int(dim)))
        treedef = jax.tree_util.tree_structure(self.rank_params[0])
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # ---- validation -------------------------------------------------------

    def validate(self, cfg=None, policy: Optional[ExecutionPolicy] = None,
                 tp: Optional[int] = None) -> "DeploymentArtifact":
        """Refuse to serve under a mismatched plan.  Returns self."""
        if cfg is not None:
            if cfg.arch_id != self.manifest["arch_id"]:
                raise PlanMismatchError(
                    f"artifact was compiled for {self.manifest['arch_id']!r}"
                    f", not {cfg.arch_id!r}")
            if config_hash(cfg) != self.manifest["config_hash"]:
                raise PlanMismatchError(
                    f"config hash {config_hash(cfg)} != artifact's "
                    f"{self.manifest['config_hash']} — the model config "
                    "changed since this plan was compiled")
        if policy is not None:
            want = policy_fields(policy)
            have = dict(self.manifest["policy"])
            # cache layout and device grid are runtime-only (see
            # policy_fields): an artifact prepared dense serves paged,
            # and dp may differ — only the TP degree (checked below
            # against the shards) is load-bearing
            for k in ("kv", "mesh"):
                want.pop(k, None)
                have.pop(k, None)
            if want != have:
                raise PlanMismatchError(
                    f"policy {want} != artifact's plan {have}")
        if tp is not None and int(tp) != self.tp:
            raise PlanMismatchError(
                f"mesh model-axis degree {tp} != artifact's TP "
                f"{self.tp} — re-run prepare for this mesh")
        return self

    # ---- (de)serialization ------------------------------------------------

    def save(self, dirpath: str) -> str:
        from repro.train import checkpoint

        if not self.rank_params:
            raise ValueError(
                "cannot re-save an artifact loaded per-rank for a mesh: "
                "this process holds only its own ranks' shards")
        os.makedirs(dirpath, exist_ok=True)
        with open(os.path.join(dirpath, MANIFEST), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        for r, tree in enumerate(self.rank_params):
            checkpoint.save(os.path.join(dirpath, f"rank_{r:02d}"), tree)
        if self.aux is not None:
            checkpoint.save(os.path.join(dirpath, "aux"), self.aux)
        return dirpath

    @classmethod
    def load_manifest(cls, dirpath: str) -> dict:
        """Read and format-check just ``manifest.json`` — the only file a
        distributed process touches before deciding which rank shards it
        owns (``load_for_mesh``)."""
        mpath = os.path.join(dirpath, MANIFEST)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"{dirpath} is not a deployment artifact (no {MANIFEST})")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("format_version") != FORMAT_VERSION:
            raise PlanMismatchError(
                f"artifact format v{manifest.get('format_version')} != "
                f"supported v{FORMAT_VERSION}")
        return manifest

    @classmethod
    def load(cls, dirpath: str) -> "DeploymentArtifact":
        from repro.train import checkpoint

        manifest = cls.load_manifest(dirpath)
        ranks = tuple(
            checkpoint.load(os.path.join(dirpath, f"rank_{r:02d}.npz"))
            for r in range(int(manifest["tp"])))
        aux_path = os.path.join(dirpath, "aux.npz")
        aux = checkpoint.load(aux_path) if os.path.exists(aux_path) else None
        return cls(manifest=manifest, rank_params=ranks, aux=aux)

    @classmethod
    def load_for_mesh(cls, dirpath: str,
                      mesh: "jax.sharding.Mesh") -> "DeploymentArtifact":
        """Distributed load (DESIGN.md §11): read only the ``rank_NN.npz``
        files whose model-axis coordinates this process's devices own and
        assemble ``global_params`` as mesh-sharded ``jax.Array`` leaves —
        no host ever materializes another rank's slices.  ``rank_params``
        is left empty; ``load_stats`` records the byte ledger."""
        from repro.dist import loader as dist_loader
        from repro.train import checkpoint

        manifest = cls.load_manifest(dirpath)
        params, stats = dist_loader.load_per_rank(dirpath, manifest, mesh)
        aux_path = os.path.join(dirpath, "aux.npz")
        aux = checkpoint.load(aux_path) if os.path.exists(aux_path) else None
        return cls(manifest=manifest, rank_params=(), aux=aux,
                   global_params=params, load_stats=stats)
