"""Offline plan compiler: quantize -> reorder/fold -> TP pre-shard.

The paper's deployment plan is known *a priori*; this package is the
offline half that makes it so in the repo — one staged pipeline from
``(ModelConfig, ExecutionPolicy, raw fp params)`` to a frozen, serialized
``DeploymentArtifact`` that the serving stack loads without touching
GPTQ or the layout planner again (prepare once, serve many).
"""

from repro.plan.artifact import DeploymentArtifact, PlanMismatchError
from repro.plan.compiler import (PlanState, compile_params, compile_plan,
                                 run_stages)

__all__ = [
    "DeploymentArtifact", "PlanMismatchError", "PlanState",
    "compile_params", "compile_plan", "run_stages",
]
