"""CLI: ``python -m repro.analysis`` — run the static verification suite.

Examples::

    python -m repro.analysis --ast                  # source hygiene only
    python -m repro.analysis --all                  # everything host-side
    python -m repro.analysis --artifact out/plan    # + offline audit
    python -m repro.analysis --all --json out.json  # machine-readable

Exit code 0 when no ``error``-severity findings (``warn``/``info`` never
gate); 1 otherwise — so CI can use the invocation directly as a gate.
"""

from __future__ import annotations

import argparse
import os
import sys

# the HLO/contract sweeps need a multi-device host platform; set BEFORE
# jax (transitively) imports, harmless when a real backend is present
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="static deployment-invariant linters (DESIGN.md §12)")
    ap.add_argument("--ast", action="store_true",
                    help="AS rules: source hygiene over src/")
    ap.add_argument("--contracts", action="store_true",
                    help="CT rules: eval_shape dtype/shape contracts")
    ap.add_argument("--hlo", action="store_true",
                    help="HL rules: compiled-HLO byte/convert/overlap sweep")
    ap.add_argument("--bench", action="store_true",
                    help="BN rules: committed BENCH_*.json schema")
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="MF rules: offline audit of a prepared "
                         "DeploymentArtifact directory")
    ap.add_argument("--all", action="store_true",
                    help="every host-side linter (AST + contracts + HLO + "
                         "bench; add --artifact for the manifest audit)")
    ap.add_argument("--tp", type=int, nargs="*", default=(2, 4, 8),
                    help="TP degrees for the contract/HLO sweeps")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the findings summary as JSON")
    args = ap.parse_args(argv)

    run_ast = args.ast or args.all
    run_contracts = args.contracts or args.all
    run_hlo = args.hlo or args.all
    run_bench = args.bench or args.all or bool(args.artifact)
    if not (run_ast or run_contracts or run_hlo or run_bench
            or args.artifact):
        ap.error("pick at least one of --ast/--contracts/--hlo/--bench/"
                 "--artifact (or --all)")

    from repro.analysis.findings import has_errors, summarize, to_json_text

    findings = []
    if run_ast:
        from repro.analysis import ast_lint
        found = ast_lint.run()
        findings += found
        print(f"ast_lint: {len(found)} finding(s)")
    if run_contracts:
        from repro.analysis import contracts
        found = contracts.run(tps=(1, *args.tp))
        findings += found
        print(f"contracts: {len(found)} finding(s)")
    if run_hlo:
        from repro.analysis import hlo_lint
        found = hlo_lint.run(tps=tuple(args.tp))
        findings += found
        print(f"hlo_lint: {len(found)} finding(s)")
    if run_bench or args.artifact:
        from repro.analysis import manifest_lint
        found = manifest_lint.run(
            artifact=args.artifact) if run_bench else (
            manifest_lint.lint_artifact(args.artifact))
        findings += found
        print(f"manifest_lint: {len(found)} finding(s)")

    for f in findings:
        print(f"  {f}")
    summary = summarize(findings)
    print(f"{len(findings)} finding(s), "
          f"{summary['counts'].get('error', 0)} error(s)")
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(to_json_text(findings))
        print(f"wrote {args.json}")
    return 1 if has_errors(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
