"""Manifest lint — offline audit of a ``DeploymentArtifact`` directory.

Everything here reads ``manifest.json`` + the ``rank_NN.npz`` /
``aux.npz`` files on disk; no mesh, no model build, no FLOPs.  The
invariants are the ones a *served* deployment would otherwise discover
at forward time (or worse, never):

* MF001 — every ``collective_plan`` entry glob resolves at least one
  real pair/fold site; an unreachable glob is a typo'd plan.
* MF002 — no entry is fully shadowed by earlier entries (matches sites,
  wins none) — shadowed entries silently serve a different collective
  than the plan text suggests.
* MF003 — every ``:fused``/``:overlap`` mark is backed by recorded
  tuner eligibility provenance AND re-derivable from the rank-0 shard
  on disk via ``kernels.dispatch.wire_support`` — a mark the kernel
  cannot serve would fall back (or die) at forward time.
* MF004 — the manifest's ``leaf_shards`` map and the ``rank_NN.npz``
  files agree: all TP files present, identical key sets, consistent
  per-rank shapes, no stray rank files beyond the TP degree.
* MF005 — every aux attention V->O fold is either consumed by the
  family's attention runtime (``SUPPORTS_ATTN_VO`` + matching
  ``ATTN_VO_PATH``) or explicitly waived (``ATTN_VO_WAIVED``) with a
  reason; folds that are neither are dead weight shipped as if live.
* MF006 — the policy's collective shorthand round-trips through
  ``parse_collective`` and agrees with the structural
  ``collective_plan`` echo.
* BN001 — committed ``BENCH_*.json`` snapshots carry the
  ``benchmarks/snapshot.py`` writer schema (git SHA, env block,
  non-empty metrics) so perf re-anchors stay machine-comparable.
"""

from __future__ import annotations

import glob as globlib
import json
import os
from typing import Optional, Sequence

from repro.analysis.findings import Finding

#: environment keys ``benchmarks.snapshot._environment`` always writes
BENCH_ENV_KEYS = ("jax", "backend", "device_count")

#: top-level keys ``benchmarks.snapshot.write`` always writes
BENCH_KEYS = ("bench", "git_sha", "created", "environment", "config",
              "metrics")


def _site_paths(manifest: dict, aux: Optional[dict]) -> list[str]:
    """Every dotted path the plan can resolve: planned MLP pairs plus
    aux attention V->O fold sites."""
    paths = [m["path"] for m in manifest.get("pairs", ())]
    for path in sorted((aux or {}).get("attn_plans", {})):
        if path not in paths:
            paths.append(path)
    return paths


def _parse_plan(manifest: dict, location: str):
    """(parsed collective, findings) from the manifest policy field."""
    from repro.comm.spec import parse_collective

    short = manifest.get("policy", {}).get("collective", "psum")
    try:
        coll = parse_collective(short)
    except ValueError as e:
        return None, [Finding(
            "MF006", f"policy collective {short!r} does not parse: {e}",
            location=location)]
    out = []
    if coll.shorthand() != short:
        out.append(Finding(
            "MF006",
            f"collective shorthand does not round-trip: {short!r} "
            f"re-serializes as {coll.shorthand()!r}",
            location=location))
    return coll, out


def lint_manifest_dict(manifest: dict, aux: Optional[dict] = None, *,
                       location: str = "manifest") -> list[Finding]:
    """Pure-dict checks (MF001/MF002/MF003-provenance/MF006) — no disk."""
    from repro.comm.spec import CollectivePlan, _match, parse_collective

    coll, out = _parse_plan(manifest, location)
    sites = _site_paths(manifest, aux)

    # MF006: structural echo must agree with the authoritative shorthand
    echo = manifest.get("collective_plan")
    if echo is not None:
        if not isinstance(coll, CollectivePlan):
            out.append(Finding(
                "MF006",
                "manifest carries a collective_plan echo but the policy "
                "collective is a bare spec",
                location=location))
        else:
            want = {"entries": [[pat, spec.shorthand()]
                                for pat, spec in coll.entries],
                    "default": coll.default.shorthand()}
            if echo != want:
                out.append(Finding(
                    "MF006",
                    "collective_plan echo disagrees with the policy "
                    "collective shorthand",
                    location=location,
                    detail={"echo": echo, "policy": want}))

    # MF001 / MF002: glob reachability over the real site list
    if isinstance(coll, CollectivePlan) and sites:
        winners: set[int] = set()
        for site in sites:
            for i, (pat, _) in enumerate(coll.entries):
                if _match(site, pat):
                    winners.add(i)
                    break
        for i, (pat, spec) in enumerate(coll.entries):
            if not any(_match(s, pat) for s in sites):
                out.append(Finding(
                    "MF001",
                    f"plan entry {pat!r} ({spec.shorthand()}) matches no "
                    f"pair or fold site — unreachable",
                    location=location, detail={"sites": sites}))
            elif i not in winners:
                out.append(Finding(
                    "MF002",
                    f"plan entry {pat!r} ({spec.shorthand()}) is fully "
                    f"shadowed by earlier entries — it never resolves",
                    location=location))

    # MF003 (provenance half): every fused/overlap mark needs a tuner
    # eligibility record that says the kernel can actually serve it
    report = {e.get("path"): e
              for e in manifest.get("collective_tuner", ())}
    for pat, short in (echo or {}).get("entries", ()):
        try:
            spec = parse_collective(short)
        except ValueError:
            continue   # already reported by the round-trip check
        if not (getattr(spec, "fused", False)
                or getattr(spec, "overlap", False)):
            continue
        entry = report.get(pat)
        if entry is None:
            out.append(Finding(
                "MF003",
                f"site {pat!r} is marked {short!r} but the manifest has "
                f"no tuner record for it — unprovenanced eligibility",
                location=location))
            continue
        elig = entry.get("eligibility")
        if spec.fused:
            if not elig:
                out.append(Finding(
                    "MF003",
                    f"site {pat!r} is marked ':fused' but its tuner "
                    f"record carries no eligibility provenance",
                    location=location))
            elif not elig.get("fusable"):
                out.append(Finding(
                    "MF003",
                    f"site {pat!r} is marked ':fused' but the recorded "
                    f"eligibility says it is not "
                    f"({elig.get('reason', 'no reason recorded')})",
                    location=location, detail=elig))
    return out


# ---------------------------------------------------------------------------
# on-disk checks
# ---------------------------------------------------------------------------

def _rank_files(dirpath: str, tp: int):
    have = sorted(globlib.glob(os.path.join(dirpath, "rank_*.npz")))
    want = [os.path.join(dirpath, f"rank_{r:02d}.npz") for r in range(tp)]
    return have, want


def _lint_rank_shards(dirpath: str, manifest: dict) -> list[Finding]:
    """MF004: leaf_shards vs what is actually on disk."""
    from repro.train import checkpoint

    out: list[Finding] = []
    tp = int(manifest["tp"])
    shards = manifest.get("leaf_shards", {})
    have, want = _rank_files(dirpath, tp)
    for path in want:
        if path not in have:
            out.append(Finding(
                "MF004",
                f"missing rank shard {os.path.basename(path)} "
                f"(manifest tp={tp})", location=dirpath))
    for path in have:
        if path not in want:
            out.append(Finding(
                "MF004",
                f"stray rank shard {os.path.basename(path)} beyond the "
                f"manifest's tp={tp} — a stale or foreign file",
                location=dirpath))
    flats = {}
    for path in want:
        if path not in have:
            continue
        r = int(os.path.basename(path)[5:7])
        flats[r] = checkpoint.flatten_keys(checkpoint.load(path))
    if not flats:
        return out
    want_keys = set(shards)
    for r, flat in sorted(flats.items()):
        keys = set(flat)
        if want_keys and keys != want_keys:
            missing = sorted(want_keys - keys)[:5]
            extra = sorted(keys - want_keys)[:5]
            out.append(Finding(
                "MF004",
                f"rank_{r:02d}.npz keys disagree with the manifest's "
                f"leaf_shards map (missing {missing}, extra {extra})",
                location=dirpath))
    ranks = sorted(flats)
    base = flats[ranks[0]]
    for r in ranks[1:]:
        for key in set(base) & set(flats[r]):
            if getattr(base[key], "shape", None) != getattr(
                    flats[r][key], "shape", None):
                out.append(Finding(
                    "MF004",
                    f"leaf {key!r} has shape {flats[r][key].shape} on "
                    f"rank {r} but {base[key].shape} on rank "
                    f"{ranks[0]} — uneven shards",
                    location=dirpath))
    return out


def _leaf_index(tree, path: str, stacked) -> object:
    """The layer-0 node at a dotted path of a (possibly stacked) tree."""
    import jax

    node = tree
    for part in path.split("."):
        node = node[part]
    lead = len(stacked or ())
    if lead:
        node = jax.tree.map(lambda a: a[(0,) * lead], node)
    return node


def _lint_fused_on_disk(dirpath: str, manifest: dict) -> list[Finding]:
    """MF003 (disk half): re-derive wire eligibility from rank 0."""
    from repro.comm.spec import parse_collective
    from repro.kernels import dispatch as kdispatch
    from repro.train import checkpoint

    out: list[Finding] = []
    marked = []
    for pat, short in manifest.get("collective_plan", {}).get(
            "entries", ()):
        try:
            spec = parse_collective(short)
        except ValueError:
            continue
        if getattr(spec, "fused", False):
            marked.append((pat, spec))
    if not marked:
        return out
    rank0 = os.path.join(dirpath, "rank_00.npz")
    if not os.path.exists(rank0):
        return out       # MF004 already reports the missing file
    tree = checkpoint.load(rank0)
    meta = {m["path"]: m for m in manifest.get("pairs", ())}
    tp = int(manifest["tp"])
    for pat, spec in marked:
        m = meta.get(pat)
        if m is None:
            continue     # an attn_vo/unknown site; provenance half covers it
        try:
            pair = _leaf_index(tree, pat, m.get("stacked"))
            ok, why = kdispatch.wire_support(pair.down, spec, tp)
        except Exception as e:
            out.append(Finding(
                "MF003",
                f"could not re-derive wire eligibility for {pat!r}: {e}",
                location=dirpath))
            continue
        if not ok:
            out.append(Finding(
                "MF003",
                f"site {pat!r} is marked ':fused' but the rank-0 shard "
                f"on disk cannot take the wire epilogue: {why}",
                location=dirpath))
    return out


def _lint_fold_coverage(manifest: dict, aux: Optional[dict], *,
                        location: str) -> list[Finding]:
    """MF005: every shipped V->O fold is consumed or explicitly waived."""
    from repro.configs import get_smoke_config
    from repro.models import registry

    out: list[Finding] = []
    plans = (aux or {}).get("attn_plans") or {}
    if not plans:
        return out
    try:
        family = get_smoke_config(manifest["arch_id"]).family
        module = registry._FAMILY_MODULES[family]
    except Exception as e:
        out.append(Finding(
            "MF005",
            f"cannot resolve family module for arch "
            f"{manifest.get('arch_id')!r}: {e}", location=location))
        return out
    consumed = (getattr(module, "ATTN_VO_PATH", None)
                if getattr(module, "SUPPORTS_ATTN_VO", False) else None)
    waived = getattr(module, "ATTN_VO_WAIVED", {})
    for path in sorted(plans):
        if path == consumed:
            continue
        if path in waived:
            out.append(Finding(
                "MF005",
                f"fold {path!r} is waived by the {family} runtime: "
                f"{waived[path]}", location=location, severity="info"))
        else:
            out.append(Finding(
                "MF005",
                f"artifact ships a V->O fold at {path!r} the {family} "
                f"attention runtime neither consumes nor waives — dead "
                f"aux weight shipped as if live",
                location=location,
                detail={"consumed": consumed,
                        "waived": sorted(waived)}))
    return out


def lint_artifact(dirpath: str) -> list[Finding]:
    """Full offline audit of one artifact directory (MF001–MF006)."""
    from repro.plan.artifact import DeploymentArtifact
    from repro.train import checkpoint

    try:
        manifest = DeploymentArtifact.load_manifest(dirpath)
    except Exception as e:
        return [Finding("MF004", f"unloadable artifact: {e}",
                        location=dirpath)]
    aux_path = os.path.join(dirpath, "aux.npz")
    aux = checkpoint.load(aux_path) if os.path.exists(aux_path) else None
    out = lint_manifest_dict(manifest, aux, location=dirpath)
    out += _lint_rank_shards(dirpath, manifest)
    out += _lint_fused_on_disk(dirpath, manifest)
    out += _lint_fold_coverage(manifest, aux, location=dirpath)
    return out


# ---------------------------------------------------------------------------
# BENCH snapshot schema (BN001)
# ---------------------------------------------------------------------------

def lint_bench_snapshots(root: Optional[str] = None,
                         paths: Optional[Sequence[str]] = None
                         ) -> list[Finding]:
    """Validate committed ``BENCH_*.json`` files against the writer."""
    if paths is None:
        if root is None:
            here = os.path.dirname(os.path.abspath(__file__))
            root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
        paths = sorted(globlib.glob(os.path.join(root, "BENCH_*.json")))
    out: list[Finding] = []
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                snap = json.load(f)
        except Exception as e:
            out.append(Finding("BN001", f"unreadable snapshot: {e}",
                               location=name))
            continue
        missing = [k for k in BENCH_KEYS if k not in snap]
        if missing:
            out.append(Finding(
                "BN001", f"snapshot is missing writer keys {missing}",
                location=name))
            continue
        stem = name[len("BENCH_"):-len(".json")]
        if snap["bench"] != stem:
            out.append(Finding(
                "BN001",
                f"snapshot 'bench' field {snap['bench']!r} does not "
                f"match its filename stem {stem!r}", location=name))
        if not snap["git_sha"]:
            out.append(Finding(
                "BN001", "snapshot carries an empty git_sha",
                location=name))
        env = snap["environment"]
        env_missing = [k for k in BENCH_ENV_KEYS if k not in env]
        if env_missing:
            out.append(Finding(
                "BN001",
                f"snapshot environment block is missing {env_missing}",
                location=name))
        if not isinstance(snap["metrics"], dict) or not snap["metrics"]:
            out.append(Finding(
                "BN001", "snapshot has no metrics", location=name))
    return out


def run(artifact: Optional[str] = None,
        root: Optional[str] = None) -> list[Finding]:
    """Entry point the CLI calls: BENCH schema + optional artifact audit."""
    out = lint_bench_snapshots(root=root)
    if artifact:
        out += lint_artifact(artifact)
    return out
