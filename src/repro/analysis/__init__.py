"""repro.analysis — static verification of deployment invariants.

Four linters, one CLI (``python -m repro.analysis``), machine-readable
findings with stable rule IDs (``findings.RULES`` is the catalog,
DESIGN.md §12 the prose):

* ``contracts``  — abstract interpretation (``jax.eval_shape``) of every
  collective strategy and model family: dtype/shape contracts proven
  with zero FLOPs (CT rules).
* ``hlo_lint``   — compiled-HLO rule engine grown out of
  ``launch/roofline.py``: measured collective bytes must equal the
  analytic ring model, no widening converts in the residual stream,
  overlap windows must span a GEMM (HL rules).
* ``ast_lint``   — source hygiene: raw ``lax`` collectives outside
  comm/+dist/, kernel calls bypassing the dispatch registry, unfrozen
  spec dataclasses, mutable defaults (AS rules).
* ``manifest_lint`` — offline ``DeploymentArtifact`` audit: plan-glob
  reachability, fused/overlap eligibility provenance re-derived from
  the shards on disk, fold coverage, BENCH snapshot schema (MF/BN
  rules).

None of these runs the model; all of them fail CI when an invariant
the serving stack depends on stops holding.
"""

from repro.analysis.findings import (Finding, Rule, RULES, has_errors,
                                     summarize, to_json_text)

__all__ = ["Finding", "Rule", "RULES", "has_errors", "summarize",
           "to_json_text"]
