"""HLO lint — comm-schedule rules decided from compiled HLO text.

Xu et al. 2025 (PAPERS.md) shows the properties this repo's past bugs
violated are fully decidable from the compiled module: wire bytes,
dtype round trips, and overlap exposure are all in the text.  This
module grows ``launch/roofline.py``'s parser (``iter_collectives`` /
``parse_overlap_windows``) into a rule engine with two surfaces:

* ``lint_hlo_text`` — rules over one module's text (a dump on disk, a
  CI artifact, a freshly lowered program):

  - HL001 when the caller supplies per-site analytic expectations
    (measured ring-model bytes must match ``bytes_on_wire``),
  - HL002 always: no *asymmetric* dtype-widening float ``convert`` (a
    narrow->wide convert whose wide->narrow partner never appears means
    the value entered the stream already narrowed — exactly how the old
    ``cast`` bf16 leak surfaces in multi-layer HLO), plus an optional
    root-dtype check against the activation input dtype,
  - HL003 when the caller expects overlap: every collective window of
    the given kinds must span a GEMM (``parse_overlap_windows``),
  - HL004 always: no ``copy`` of a donated (input/output aliased)
    parameter.

* ``run_site_sweep`` — the self-contained deployment check: for every
  (collective spec × TP degree) site it compiles the paper's pair
  program under ``schemes.pair_forward_tp`` exactly like
  ``benchmarks/bench_comm.py`` does and asserts measured == analytic
  (rel diff < 1e-6) per site, overlap exposure for ``:overlap`` specs,
  and the dtype rules over every lowered module.
"""

from __future__ import annotations

import functools
import re
from typing import Iterable, Optional, Sequence

from repro.analysis.findings import Finding
from repro.launch import roofline

#: HL001 tolerance — the byte model and the implementation are the same
#: padded two-phase ring, so agreement is exact up to float accounting
BYTE_RTOL = 1e-6

#: float dtypes (HLO names) ordered by width, for the widening check
_FLOAT_BYTES = {"f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
                "f32": 4, "f64": 8}

# "%c = f32[8,16]{1,0} convert(bf16[8,16]{1,0} %x)" -> (f32, bf16)
_CONVERT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[[\d,]*\]\S*\s+convert\(([a-z0-9]+)\[")
# ENTRY signature result dtype: "... -> f32[8,256] {" / tuple forms skipped
_ENTRY_ROOT_RE = re.compile(r"^ENTRY\s[^\n]*->\s*([a-z0-9]+)\[", re.M)
# donated params: input_output_alias={ {0}: (1, {}, MAY_ALIAS), ... } —
# the first element of each (param_number, param_index, kind) tuple
_ALIAS_PARAM_RE = re.compile(r"\(\s*(\d+)\s*,")
# "%p.1 = f32[8]{0} parameter(0)" -> (name, number)
_PARAM_RE = re.compile(
    r"%?([A-Za-z0-9_.\-]+)\s*=\s*\S+\s+parameter\((\d+)\)")
# "%copy.3 = f32[8]{0} copy(f32[8]{0} %p.1)" -> operand name
_COPY_RE = re.compile(
    r"%?([A-Za-z0-9_.\-]+)\s*=\s*\S+\s+copy\((?:\S+\s+)?%([A-Za-z0-9_.\-]+)\)")


def _widening_converts(hlo_text: str) -> list[Finding]:
    """HL002: asymmetric narrow->wide float converts.

    A well-formed wire round trip narrows before the collective and
    widens after — both directions appear, the pair cancels.  A widening
    convert with no matching narrowing convert anywhere in the module
    means the residual stream was already narrow when it arrived:
    information was lost upstream of the widen.
    """
    pairs: dict[tuple, int] = {}
    lines: dict[tuple, int] = {}
    for lineno, line in enumerate(hlo_text.splitlines(), 1):
        m = _CONVERT_RE.search(line)
        if not m:
            continue
        to_dt, from_dt = m.groups()
        if to_dt not in _FLOAT_BYTES or from_dt not in _FLOAT_BYTES:
            continue  # int<->float converts are quantization, not leaks
        key = (from_dt, to_dt)
        pairs[key] = pairs.get(key, 0) + 1
        lines.setdefault(key, lineno)
    out = []
    for (from_dt, to_dt), n in sorted(pairs.items()):
        if _FLOAT_BYTES[to_dt] <= _FLOAT_BYTES[from_dt]:
            continue  # narrowing or same-width: never a leak by itself
        if (to_dt, from_dt) in pairs:
            continue  # matched round trip (intended wire compression)
        out.append(Finding(
            "HL002",
            f"{n} widening convert(s) {from_dt}->{to_dt} with no "
            f"matching {to_dt}->{from_dt} narrowing — the residual "
            f"stream entered {from_dt} upstream",
            location=f"hlo:{lines[(from_dt, to_dt)]}",
            detail={"from": from_dt, "to": to_dt, "count": n}))
    return out


def _root_dtype(hlo_text: str) -> Optional[str]:
    m = _ENTRY_ROOT_RE.search(hlo_text)
    return m.group(1) if m else None


def _alias_block(hlo_text: str) -> Optional[str]:
    """The brace-balanced body of ``input_output_alias={...}`` (the
    nested ``{0}: (1, {}, ...)`` tuples make a regex fragile)."""
    tag = "input_output_alias={"
    start = hlo_text.find(tag)
    if start < 0:
        return None
    depth, i = 1, start + len(tag)
    while i < len(hlo_text) and depth:
        depth += {"{": 1, "}": -1}.get(hlo_text[i], 0)
        i += 1
    return hlo_text[start + len(tag):i - 1]


def _donated_copies(hlo_text: str) -> list[Finding]:
    """HL004: copy instructions whose operand is an aliased parameter."""
    block = _alias_block(hlo_text)
    if block is None:
        return []
    donated_nums = set(_ALIAS_PARAM_RE.findall(block))
    if not donated_nums:
        return []
    donated_names = {name for name, num in _PARAM_RE.findall(hlo_text)
                     if num in donated_nums}
    out = []
    for lineno, line in enumerate(hlo_text.splitlines(), 1):
        mc = _COPY_RE.search(line)
        if mc and mc.group(2) in donated_names:
            out.append(Finding(
                "HL004",
                f"copy of donated parameter %{mc.group(2)} — the "
                f"donation buys nothing if XLA duplicates the buffer",
                location=f"hlo:{lineno}",
                detail={"copy": mc.group(1), "param": mc.group(2)}))
    return out


def lint_hlo_text(hlo_text: str, *, chips: int = 1,
                  expected_bytes: Optional[dict] = None,
                  expect_root_dtype: Optional[str] = None,
                  expect_overlap_kinds: Optional[Sequence[str]] = None,
                  location: str = "hlo") -> list[Finding]:
    """Apply every text-decidable rule to one compiled module.

    ``expected_bytes``: ``{site_label: analytic_bytes}`` — the module's
    measured per-device collective total must match the summed analytic
    prediction within ``BYTE_RTOL`` (HL001).  ``expect_root_dtype``:
    the activation input dtype (HLO name, e.g. ``"f32"``) the ENTRY
    root must preserve (HL002).  ``expect_overlap_kinds``: collective
    kinds whose windows must span a GEMM (HL003).
    """
    out: list[Finding] = []
    if expected_bytes:
        measured = roofline.parse_collective_bytes(
            hlo_text, chips=chips)["total_per_device"]
        analytic = sum(expected_bytes.values())
        rel = abs(measured - analytic) / max(analytic, 1.0)
        if rel > BYTE_RTOL:
            out.append(Finding(
                "HL001",
                f"measured collective bytes {measured:.1f} != analytic "
                f"{analytic:.1f} (rel diff {rel:.2e} > {BYTE_RTOL})",
                location=location,
                detail={"measured": measured, "analytic": analytic,
                        "rel": rel, "sites": dict(expected_bytes)}))
    out.extend(_widening_converts(hlo_text))
    if expect_root_dtype is not None:
        root = _root_dtype(hlo_text)
        if root is not None and root != expect_root_dtype:
            out.append(Finding(
                "HL002",
                f"ENTRY root dtype {root} != activation input dtype "
                f"{expect_root_dtype} — a wire dtype leaked out of the "
                f"residual stream",
                location=location,
                detail={"root": root, "expect": expect_root_dtype}))
    if expect_overlap_kinds:
        win = roofline.parse_overlap_windows(
            hlo_text, kinds=tuple(expect_overlap_kinds))
        if win["collectives"] == 0:
            out.append(Finding(
                "HL003",
                f"':overlap' promised a decomposed ring but the module "
                f"has no {'/'.join(expect_overlap_kinds)} instruction",
                location=location, detail=win))
        elif win["spanning"] == 0:
            out.append(Finding(
                "HL003",
                f"no collective window spans a GEMM "
                f"({win['collectives']} windows, all exposed) — the "
                f"':overlap' schedule serializes",
                location=location,
                detail={k: win[k] for k in ("collectives", "spanning")}))
    out.extend(_donated_copies(hlo_text))
    return out


# ---------------------------------------------------------------------------
# self-contained site sweep (compiled pair programs, bench_comm's setup)
# ---------------------------------------------------------------------------

#: specs whose measured==analytic equality PR 5 established exactly;
#: ``cast`` is excluded on CPU — XLA promotes the bf16 all-reduce to f32
#: (the wire stays bf16 on TPU), a backend artifact, not a plan bug
SWEEP_SPECS = ("psum", "psum_scatter", "quant-int8", "quant-int4")

#: ':overlap' variants checked for pipelined exposure (block 32 divides
#: the per-rank chunk at every swept TP degree)
SWEEP_OVERLAP_SPECS = ("quant-int8:32:overlap", "quant-int4:32:overlap")

_SWEEP_SHAPE = (256, 512, 256)   # (k1, n1, n2): shards to tp 8, gs 32
_SWEEP_M = 8


@functools.lru_cache(maxsize=None)
def _sweep_pair():
    import jax
    import jax.numpy as jnp

    from repro.core import reorder

    k1, n1, n2 = _SWEEP_SHAPE
    rng = jax.random.PRNGKey(0)
    r = jax.random.split(rng, 2)
    w_up = jax.random.normal(r[0], (k1, n1), jnp.float32) * 0.02
    w_down = jax.random.normal(r[1], (n1, n2), jnp.float32) * 0.02
    return reorder.plan_pair(w_up, w_down, scheme="tp-aware",
                             group_size_up=32, group_size_down=32, rng=rng)


def _lowered_pair_hlo(spec, tp: int) -> str:
    import jax
    import jax.numpy as jnp

    from repro.core.policy import ExecutionPolicy

    pp = _sweep_pair()
    mesh = jax.make_mesh((1, tp), ("data", "model"),
                         devices=jax.devices()[:tp])
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (_SWEEP_M, _SWEEP_SHAPE[0]), jnp.float32)
    pol = ExecutionPolicy(scheme="tp-aware", backend="jnp",
                          compute_dtype=jnp.float32, collective=spec)
    with mesh:
        fn = lambda xx, p: p.forward(xx, pol, mesh, activation=None)
        return jax.jit(fn).lower(x, pp).compile().as_text()


def run_site_sweep(tps: Iterable[int] = (2, 4, 8),
                   specs: Optional[Sequence] = None) -> list[Finding]:
    """Compile one pair program per (spec × tp) and lint every rule.

    TP degrees beyond the host's device count are skipped (the CLI
    forces 8 host devices; under CI's 2-device job only tp=2 runs).
    """
    import jax

    from repro.comm.spec import CollectiveSpec

    if specs is None:
        specs = [CollectiveSpec.parse(s) for s in SWEEP_SPECS]
        specs += [CollectiveSpec.parse(s) for s in SWEEP_OVERLAP_SPECS]
    else:
        specs = [CollectiveSpec.parse(s) for s in specs]

    out: list[Finding] = []
    n2 = _SWEEP_SHAPE[2]
    for tp in tps:
        if tp > len(jax.devices()):
            continue
        for spec in specs:
            label = f"pair@tp={tp}:{spec.shorthand()}"
            txt = _lowered_pair_hlo(spec, tp)
            out.extend(lint_hlo_text(
                txt, chips=tp,
                expected_bytes={label: spec.bytes_on_wire(
                    (_SWEEP_M, n2), tp)},
                expect_root_dtype="f32",
                expect_overlap_kinds=(("collective-permute",)
                                      if spec.overlap else None),
                location=label))
    return out


def run(tps: Iterable[int] = (2, 4, 8)) -> list[Finding]:
    """Entry point the CLI calls."""
    return run_site_sweep(tps=tps)
