"""Findings + rule catalog — the analysis subsystem's shared vocabulary.

Every linter in ``repro.analysis`` (contracts / hlo_lint / ast_lint /
manifest_lint) reports through the same machine-readable shape: a
``Finding`` carrying a rule ID from the central ``RULES`` catalog, a
severity, a location, and a free-form ``detail`` payload.  The catalog
is the single source of truth the CLI, the tests, and DESIGN.md §12
enumerate — a linter cannot emit an unregistered rule ID
(``Finding.__post_init__`` refuses), so the documented catalog and the
enforced catalog can never drift.

Severities:

* ``error`` — an invariant the deployment plan promises is violated;
  the CLI exits non-zero (the CI gate).
* ``warn``  — suspicious but not provably wrong (e.g. a copy of a
  donated buffer XLA may have legitimate reasons for).
* ``info``  — a recorded, intentional waiver (e.g. an attention fold a
  family documents as not consumable).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

SEVERITIES = ("error", "warn", "info")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One catalog entry: the invariant a linter enforces."""

    id: str
    layer: str          # contracts | hlo | ast | manifest
    severity: str       # default severity findings of this rule carry
    invariant: str      # one-line statement of what must hold
    caught: str         # which past bug class this rule would have caught


#: the rule catalog — DESIGN.md §12 is generated from this table's
#: fields, and ``Finding`` refuses IDs that are not in it.
RULES: dict[str, Rule] = {r.id: r for r in [
    # ---- contracts.py (abstract interpretation, no FLOPs) -----------------
    Rule("CT001", "contracts", "error",
         "every collective strategy returns the residual-stream input "
         "dtype and the contracted shape under jax.eval_shape at every "
         "TP degree",
         "the 'cast' strategy leaking bf16 into the f32 residual stream "
         "(compounding rounding per layer; fixed in comm/dispatch)"),
    Rule("CT002", "contracts", "error",
         "at TP=1 every collective spec is the identity and its analytic "
         "bytes_on_wire is exactly zero",
         "single-rank deployments paying a quantize/dequantize round "
         "trip (or wire bytes) for a collective that moves nothing"),
    Rule("CT003", "contracts", "error",
         "paged and dense KV caches of a family agree on per-token "
         "geometry (kv-heads, head_dim) and payload dtype",
         "a paged pool allocated with the wrong head grid decoding "
         "garbage only once a sequence crosses its first page boundary"),
    Rule("CT004", "contracts", "error",
         "every registered model family's forward/decode emits f32 "
         "logits from abstract params (jax.eval_shape, zero FLOPs)",
         "low-bit accumulation dtypes escaping through the lm_head and "
         "silently degrading sampling entropy"),
    # ---- hlo_lint.py (compiled-HLO rule engine) ---------------------------
    Rule("HL001", "hlo", "error",
         "collective bytes measured from compiled HLO equal the spec's "
         "analytic bytes_on_wire per resolved site (ring cost model, "
         "rel diff < 1e-6)",
         "the quant-int8/int4 gather fallback burning tp/2 x the "
         "analytic wire bytes before the padded two-phase ring landed"),
    Rule("HL002", "hlo", "error",
         "no dtype-widening float convert in the residual stream whose "
         "matching narrowing convert is absent (an asymmetric widening "
         "means the stream was already narrow), and the program's root "
         "keeps the activation input dtype",
         "the pre-fix 'cast' collective returning its bf16 wire dtype: "
         "the residual add widened it back every layer, visible in HLO "
         "as an unmatched bf16->f32 convert"),
    Rule("HL003", "hlo", "error",
         "every ':overlap' site's collective window spans at least one "
         "GEMM in the scheduled module (parse_overlap_windows)",
         "a sync ring where ':overlap' promised a pipelined one — the "
         "epilogue serializes and the microbatching is pure overhead"),
    Rule("HL004", "hlo", "warn",
         "no copy instruction duplicates a donated (input/output "
         "aliased) parameter",
         "donated KV-cache buffers silently copied per decode step, "
         "doubling cache HBM and hiding the donation's benefit"),
    # ---- ast_lint.py (source-tree checks) ---------------------------------
    Rule("AS001", "ast", "error",
         "no raw jax.lax collective (psum/psum_scatter/all_gather/"
         "ppermute/all_to_all/pmean) outside comm/ and dist/",
         "call sites bypassing the comm registry so per-layer plans, "
         "wire accounting, and the dtype contract silently don't apply"),
    Rule("AS002", "ast", "error",
         "no kernel invocation (kernels.ops / kernels.ref entry points) "
         "bypasses the kernels/dispatch.py registry",
         "a call pinned to one backend skipping dispatch's availability "
         "fallback and the policy's backend selection"),
    Rule("AS003", "ast", "error",
         "every dataclass in a spec module (core/policy.py, comm/spec.py"
         ", cache/spec.py, dist/topology.py) is frozen",
         "a mutable spec mutating after being hashed as a jit static "
         "argument — stale compilation caches keyed on the old value"),
    Rule("AS004", "ast", "error",
         "no mutable default argument (list/dict/set literals) in src/",
         "a shared default accumulating state across calls (classic "
         "aliasing bug; none shipped, the rule keeps it that way)"),
    # ---- manifest_lint.py (offline artifact audit) ------------------------
    Rule("MF001", "manifest", "error",
         "every CollectivePlan entry glob matches at least one site the "
         "artifact actually planned (pairs + attention folds)",
         "a tuned plan entry orphaned by a rename resolving every site "
         "to the default psum while the manifest still advertises "
         "quantized epilogues"),
    Rule("MF002", "manifest", "error",
         "no CollectivePlan entry is shadowed (every entry is the first "
         "match for at least one planned site)",
         "an earlier catch-all glob silently overriding a later, more "
         "specific per-layer choice"),
    Rule("MF003", "manifest", "error",
         "every ':fused'/':overlap' mark is backed by recorded "
         "eligibility provenance AND by kernels.dispatch.wire_support "
         "re-derived from the rank-0 shard on disk",
         "a plan marked ':fused' whose serve-time wire_support check "
         "fails — the runtime silently falls back to the dense epilogue "
         "while dashboards report the fused one"),
    Rule("MF004", "manifest", "error",
         "the manifest's leaf_shards map matches the rank_NN.npz files "
         "on disk: tp files present, every key in every rank, no "
         "unlisted keys, shard shapes consistent across ranks",
         "a hand-pruned artifact directory serving a rank tree that "
         "silently reassembles the wrong global tensor"),
    Rule("MF005", "manifest", "error",
         "every aux attention V->O fold is either consumed by the "
         "family's runtime (ATTN_VO_PATH) or explicitly waived "
         "(ATTN_VO_WAIVED, reported as info)",
         "whisper's decoder folds riding every artifact as dead weight "
         "while the runtime recomputed the unfolded projections"),
    Rule("MF006", "manifest", "error",
         "the manifest's collective shorthand parses and round-trips, "
         "and the structural collective_plan echo agrees with it",
         "a manifest edited by hand serving a different plan than the "
         "one its provenance block displays"),
    Rule("BN001", "manifest", "error",
         "every committed BENCH_*.json matches benchmarks/snapshot.py's "
         "writer schema: bench name == filename, git_sha, created, "
         "environment{jax, backend, device_count}, config, non-empty "
         "metrics",
         "a stale or hand-edited snapshot anchoring future perf "
         "comparisons to numbers no writer produced"),
]}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One linter result, machine-readable.

    ``location`` is layer-appropriate: ``file:line`` for AST findings,
    a pair path / spec shorthand for plan findings, an HLO instruction
    name for compiled findings.
    """

    rule: str
    message: str
    location: str = ""
    severity: Optional[str] = None      # None -> the rule's default
    detail: Any = None

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(
                f"finding uses unregistered rule id {self.rule!r}; "
                f"catalog: {sorted(RULES)}")
        sev = self.severity or RULES[self.rule].severity
        if sev not in SEVERITIES:
            raise ValueError(f"unknown severity {sev!r}")
        object.__setattr__(self, "severity", sev)

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "layer": RULES[self.rule].layer,
            "location": self.location,
            "message": self.message,
            "detail": self.detail,
        }

    def __str__(self) -> str:
        loc = f" {self.location}" if self.location else ""
        return f"[{self.rule}/{self.severity}]{loc}: {self.message}"


def summarize(findings) -> dict:
    """The CLI's JSON report: catalog + findings + exit-worthy counts."""
    findings = list(findings)
    return {
        "findings": [f.to_json() for f in findings],
        "counts": {sev: sum(1 for f in findings if f.severity == sev)
                   for sev in SEVERITIES},
        "rules_checked": sorted(RULES),
    }


def to_json_text(findings) -> str:
    return json.dumps(summarize(findings), indent=1, sort_keys=True)


def has_errors(findings) -> bool:
    return any(f.severity == "error" for f in findings)
