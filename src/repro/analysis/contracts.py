"""Contract lint — abstract interpretation of the deployment plan.

Everything here runs under ``jax.eval_shape``: the programs are traced
with shape/dtype avals only, so the whole pass spends **zero FLOPs** and
never allocates a model — Hansen-Palmus et al. 2024's observation that
dtype/wire-bit contracts are exactly where compressed-TP deployments
silently lose quality, made checkable before a single token is served.

* CT001 — for every collective spec × TP degree, tracing the strategy's
  ``apply`` inside ``shard_map`` must return the residual stream's input
  dtype (f32 AND bf16 streams) and the contracted shape (full for
  all-reduce strategies, last-dim sharded for scatter strategies).
* CT002 — at TP=1 every spec is the identity (shape AND dtype) and its
  analytic ``bytes_on_wire`` is exactly zero.
* CT003 — per registered family with a paged cache: the dense and paged
  KV trees agree on per-token geometry (kv-heads × head_dim trailing
  dims) and payload dtype.
* CT004 — per registered family: forward and decode_step emit f32
  logits from fully abstract params (``Model.init`` under eval_shape —
  the GPTQ/reorder/fold pipeline traces abstractly too).

With ``specs=None`` the collective checks sweep every registered
strategy plus the ``:overlap`` quant variants; a caller holding a
prepared artifact passes that plan's resolved ``specs()`` instead so
the exact deployed sites are what gets verified.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

from repro.analysis.findings import Finding

#: the residual-stream dtypes the collective contract must preserve
STREAM_DTYPES = ("float32", "bfloat16")

#: (rows, cols) of the abstract partial sum the collectives close;
#: cols is divisible by every swept tp (and tp*8 for packed int4)
PROBE_SHAPE = (8, 256)


def _default_specs():
    from repro.comm import dispatch as comm_dispatch
    from repro.comm.spec import CollectiveSpec

    out = [CollectiveSpec.parse(n) for n in comm_dispatch.strategies()]
    out += [CollectiveSpec.parse("quant-int8:32:overlap"),
            CollectiveSpec.parse("quant-int4:32:overlap")]
    return out


def _tp_mesh(tp: int):
    import jax

    return jax.make_mesh((tp,), ("model",), devices=jax.devices()[:tp])


def _abstract_apply(spec, tp: int, dtype):
    """eval_shape of the strategy closing a replicated partial sum over a
    ``tp``-way model axis; returns the output ShapeDtypeStruct."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comm import dispatch as comm_dispatch
    from repro.core import compat
    from repro.core.policy import ExecutionPolicy

    mesh = _tp_mesh(tp)
    policy = ExecutionPolicy(collective=spec)
    scatters = comm_dispatch.scatters_output(spec)
    out_spec = P(None, "model") if scatters else P(None, None)
    fn = compat.shard_map(
        lambda y: comm_dispatch.apply(y, "model", spec, policy),
        mesh=mesh, in_specs=P(None, None), out_specs=out_spec)
    y = jax.ShapeDtypeStruct(PROBE_SHAPE, jnp.dtype(dtype))
    return jax.eval_shape(fn, y)


def lint_collectives(specs: Optional[Sequence] = None,
                     tps: Iterable[int] = (1, 2, 4, 8)) -> list[Finding]:
    """CT001 + CT002 over every (spec × tp × stream dtype) site."""
    import jax
    import jax.numpy as jnp

    from repro.comm.spec import CollectiveSpec

    if specs is None:
        specs = _default_specs()
    else:
        specs = [CollectiveSpec.parse(s) for s in specs]

    out: list[Finding] = []
    for spec in specs:
        short = spec.shorthand()
        # CT002: TP=1 — zero wire bytes, identity shape/dtype
        b1 = spec.bytes_on_wire(PROBE_SHAPE, 1)
        if b1 != 0.0:
            out.append(Finding(
                "CT002",
                f"bytes_on_wire at tp=1 is {b1}, not 0 — a single-rank "
                f"deployment would be billed for wire traffic",
                location=short, detail={"bytes": b1}))
        for dtype in STREAM_DTYPES:
            try:
                o1 = _abstract_apply(spec, 1, dtype)
            except Exception as e:     # tracing itself must succeed
                out.append(Finding(
                    "CT002", f"abstract apply failed at tp=1: {e}",
                    location=f"{short}[{dtype}]"))
                continue
            if (o1.shape, str(o1.dtype)) != (
                    PROBE_SHAPE, str(jnp.dtype(dtype))):
                out.append(Finding(
                    "CT002",
                    f"tp=1 is not the identity: {dtype}{PROBE_SHAPE} -> "
                    f"{o1.dtype}{o1.shape}",
                    location=f"{short}[{dtype}]"))
        # CT001: dtype stability at every TP degree with enough devices
        for tp in tps:
            if tp == 1 or tp > len(jax.devices()):
                continue
            # scatter strategies return a (8, n/tp) local shard; the
            # out_specs concatenation makes the GLOBAL aval (8, n) for
            # every strategy — a strategy returning the wrong local
            # shape therefore shows up as a wrong global shape here
            want_shape = PROBE_SHAPE
            for dtype in STREAM_DTYPES:
                loc = f"{short}[{dtype}]@tp={tp}"
                try:
                    o = _abstract_apply(spec, tp, dtype)
                except Exception as e:
                    out.append(Finding(
                        "CT001", f"abstract apply failed: {e}",
                        location=loc))
                    continue
                if str(o.dtype) != str(jnp.dtype(dtype)):
                    out.append(Finding(
                        "CT001",
                        f"collective returns {o.dtype}, not the residual "
                        f"stream's {dtype} — a wire dtype leaks into the "
                        f"caller",
                        location=loc,
                        detail={"got": str(o.dtype), "want": dtype}))
                if o.shape != want_shape:
                    out.append(Finding(
                        "CT001",
                        f"collective returns shape {o.shape}, contract "
                        f"says {want_shape}",
                        location=loc,
                        detail={"got": list(o.shape),
                                "want": list(want_shape)}))
    return out


# ---------------------------------------------------------------------------
# model-family contracts
# ---------------------------------------------------------------------------

def _family_smoke_cfgs():
    """One smoke config per registered family (first matching arch)."""
    from repro.configs import ARCH_IDS, get_smoke_config

    seen = {}
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        seen.setdefault(cfg.family, cfg)
    return seen


def _kv_geometry_leaves(tree, kvh: int, hd: int):
    """(path, aval) of float KV payload leaves (ndim >= 4), and whether
    each ends with the family's (kv_heads, head_dim) token geometry."""
    import jax
    import jax.numpy as jnp

    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if leaf.ndim < 4 or not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        name = jax.tree_util.keystr(path)
        out.append((name, leaf, leaf.shape[-2:] == (kvh, hd)))
    return out


def lint_families(batch: int = 2, seq: int = 16) -> list[Finding]:
    """CT003 + CT004 over every registered model family (smoke shapes)."""
    import jax
    import jax.numpy as jnp

    from repro.models import common as cm
    from repro.models.common import REPLICATED
    from repro.models.registry import build_model

    out: list[Finding] = []
    for family, cfg in sorted(_family_smoke_cfgs().items()):
        model = build_model(cfg)
        loc = f"{family}/{cfg.arch_id}"
        # CT004: abstract init -> forward -> f32 logits, no FLOPs
        try:
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            batch_st = model.batch_shape_structs(batch, seq)
            logits = jax.eval_shape(
                lambda p, b: model.forward(p, b, REPLICATED),
                params, batch_st)
        except Exception as e:
            out.append(Finding(
                "CT004", f"abstract forward failed: {e}", location=loc))
            continue
        if str(logits.dtype) != "float32":
            out.append(Finding(
                "CT004",
                f"forward logits are {logits.dtype}, not float32",
                location=loc, detail={"got": str(logits.dtype)}))
        if logits.shape != (batch, seq, cfg.vocab_size):
            out.append(Finding(
                "CT004",
                f"forward logits shape {logits.shape} != "
                f"{(batch, seq, cfg.vocab_size)}",
                location=loc))
        try:
            cache = jax.eval_shape(
                lambda: model.init_cache(batch, seq))
            tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            dec, _ = jax.eval_shape(
                lambda p, c, t, q: model.decode_step(p, c, t, q,
                                                     REPLICATED),
                params, cache, tok, pos)
        except Exception as e:
            out.append(Finding(
                "CT004", f"abstract decode_step failed: {e}",
                location=loc))
            continue
        if str(dec.dtype) != "float32":
            out.append(Finding(
                "CT004",
                f"decode logits are {dec.dtype}, not float32",
                location=loc, detail={"got": str(dec.dtype)}))
        # CT003: dense vs paged cache geometry agreement
        if not model.supports_paged:
            continue
        kvh, _, _ = cm.head_grid(cfg)
        hd = cfg.head_dim
        try:
            paged = jax.eval_shape(
                lambda: model.init_paged_cache(batch, 8, 8))
        except Exception as e:
            out.append(Finding(
                "CT003", f"abstract paged cache failed: {e}",
                location=loc))
            continue
        dense_kv = _kv_geometry_leaves(cache, kvh, hd)
        paged_kv = _kv_geometry_leaves(paged, kvh, hd)
        for which, leaves in (("dense", dense_kv), ("paged", paged_kv)):
            for name, leaf, ok in leaves:
                if not ok:
                    out.append(Finding(
                        "CT003",
                        f"{which} cache leaf {name} has trailing dims "
                        f"{leaf.shape[-2:]}, family geometry is "
                        f"({kvh}, {hd})",
                        location=loc))
        d_dtypes = {str(leaf.dtype) for _, leaf, _ in dense_kv}
        p_dtypes = {str(leaf.dtype) for _, leaf, _ in paged_kv}
        if d_dtypes != p_dtypes:
            out.append(Finding(
                "CT003",
                f"dense cache payload dtypes {sorted(d_dtypes)} != "
                f"paged {sorted(p_dtypes)}",
                location=loc))
    return out


def run(specs: Optional[Sequence] = None,
        tps: Iterable[int] = (1, 2, 4, 8)) -> list[Finding]:
    """Entry point the CLI calls: collective + family contracts."""
    return lint_collectives(specs=specs, tps=tps) + lint_families()
