"""AST lint — source-tree invariants, no imports of the checked code.

Walks every ``.py`` under ``src/`` with the stdlib ``ast`` module (the
checked modules are never imported, so a syntax-valid tree lints in
milliseconds and a broken one is reported instead of crashing the
linter's own process):

* AS001 — raw ``jax.lax`` collectives outside ``comm/`` + ``dist/``.
  The comm registry is the only place allowed to issue collectives
  (plus ``dist/`` for the decomposed overlap ring); anywhere else the
  per-layer plan, the wire-byte accounting, and the dtype contract
  silently don't apply.
* AS002 — kernel entry points (``kernels.ops`` / ``kernels.ref``
  functions) called outside ``kernels/`` — everything must route
  through ``kernels/dispatch.py``'s registry.
* AS003 — non-frozen dataclasses in spec modules.  Specs are hashed as
  jit static arguments; a mutable spec is a stale-compilation-cache bug
  waiting to happen.
* AS004 — mutable default arguments anywhere in ``src/``.
"""

from __future__ import annotations

import ast
import os
from typing import Iterable, Optional

from repro.analysis.findings import Finding

#: collective primitives the comm layer owns
COLLECTIVE_NAMES = frozenset({
    "psum", "psum_scatter", "all_gather", "ppermute", "all_to_all",
    "pmean", "pshuffle",
})

#: directories (repo-relative, '/'-normalized) allowed to issue raw
#: collectives: the strategy registry itself and the decomposed ring
COLLECTIVE_ALLOWED_DIRS = ("repro/comm/", "repro/dist/")

#: kernel entry-point names only ``kernels/`` may call directly
KERNEL_ENTRY_NAMES = frozenset({
    "pallas_dequant_matmul_ordered", "pallas_dequant_matmul_gidx",
    "dequant_matmul_wire", "dequant_matmul",
})
KERNEL_ALLOWED_DIRS = ("repro/kernels/",)

#: spec modules whose dataclasses must all be frozen (jit-static specs)
SPEC_MODULES = (
    "repro/core/policy.py",
    "repro/comm/spec.py",
    "repro/cache/spec.py",
    "repro/dist/topology.py",
)


def _attr_chain(node: ast.AST) -> Optional[str]:
    """``jax.lax.psum`` -> "jax.lax.psum"; None for non-name chains."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        return chain in ("list", "dict", "set")
    return False


def _dataclass_frozen(dec: ast.AST) -> Optional[bool]:
    """True/False for a dataclass decorator, None for other decorators."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    chain = _attr_chain(target)
    if chain is None or chain.split(".")[-1] != "dataclass":
        return None
    if not isinstance(dec, ast.Call):
        return False                      # bare @dataclass
    for kw in dec.keywords:
        if kw.arg == "frozen":
            return (isinstance(kw.value, ast.Constant)
                    and bool(kw.value.value))
    return False


def _under(rel: str, dirs) -> bool:
    return any(rel.startswith(d) for d in dirs)


def lint_source(src: str, rel: str) -> list[Finding]:
    """Lint one module's source text (``rel``: '/'-normalized path
    relative to the ``src/`` root, e.g. ``"repro/core/schemes.py"``)."""
    out: list[Finding] = []
    try:
        tree = ast.parse(src, filename=rel)
    except SyntaxError as e:
        return [Finding("AS004", f"unparseable module: {e.msg}",
                        location=f"{rel}:{e.lineno or 0}")]

    check_collectives = not _under(rel, COLLECTIVE_ALLOWED_DIRS)
    check_kernels = not _under(rel, KERNEL_ALLOWED_DIRS)
    spec_module = any(rel.endswith(m) or rel == m for m in SPEC_MODULES)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain is None:
                continue
            head, leaf = chain.split(".")[0], chain.split(".")[-1]
            # AS001: lax.psum(...) / jax.lax.all_gather(...) etc.; the
            # module-qualified form is the only way these are spelled
            # (a bare `psum(...)` import is matched too, conservatively)
            if (check_collectives and leaf in COLLECTIVE_NAMES
                    and ("lax" in chain.split(".") or chain == leaf)):
                out.append(Finding(
                    "AS001",
                    f"raw collective {chain}() outside comm//dist/ — "
                    f"route it through repro.comm.dispatch",
                    location=f"{rel}:{node.lineno}"))
            # AS002: ops.pallas_dequant_matmul_*(...) / ref.dequant_matmul
            if (check_kernels and leaf in KERNEL_ENTRY_NAMES
                    and head != "kdispatch"):
                out.append(Finding(
                    "AS002",
                    f"kernel entry point {chain}() bypasses "
                    f"kernels/dispatch.py",
                    location=f"{rel}:{node.lineno}"))
        elif isinstance(node, ast.ClassDef) and spec_module:
            for dec in node.decorator_list:
                frozen = _dataclass_frozen(dec)
                if frozen is False:
                    out.append(Finding(
                        "AS003",
                        f"spec dataclass {node.name} is not frozen=True "
                        f"(specs are hashed as jit static arguments)",
                        location=f"{rel}:{node.lineno}"))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            for default in list(args.defaults) + [
                    d for d in args.kw_defaults if d is not None]:
                if _is_mutable_literal(default):
                    out.append(Finding(
                        "AS004",
                        f"mutable default argument in {node.name}()",
                        location=f"{rel}:{default.lineno}"))
    return out


def lint_tree(root: str) -> list[Finding]:
    """Lint every ``.py`` under ``root`` (the ``src/`` directory)."""
    out: list[Finding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                out.extend(lint_source(f.read(), rel))
    return out


def run(src_root: Optional[str] = None) -> list[Finding]:
    """Entry point the CLI calls: lint the repo's ``src/`` tree."""
    if src_root is None:
        # .../src/repro/analysis/ast_lint.py -> .../src
        here = os.path.dirname(os.path.abspath(__file__))
        src_root = os.path.dirname(os.path.dirname(here))
    return lint_tree(src_root)
