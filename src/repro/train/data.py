"""Token data pipeline: synthetic stream + file-backed corpus.

Host-side (numpy) batching with per-host sharding: each host slices its
``process_index`` stripe of the global batch, the standard multi-pod JAX
input pattern (`jax.make_array_from_process_local_data` when running on a
real multi-host mesh; plain device_put on single host).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    path: Optional[str] = None    # None -> synthetic stream


def _synthetic_stream(cfg: DataConfig) -> Iterator[np.ndarray]:
    """Deterministic synthetic corpus: Zipfian unigram + Markov bigram mix
    (learnable structure, so loss actually falls during the examples)."""
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    # Zipf unigram
    probs = 1.0 / np.arange(1, v + 1) ** 1.1
    probs /= probs.sum()
    # sparse deterministic bigram: each token has a preferred successor
    succ = rng.permutation(v)
    while True:
        b = rng.random((cfg.global_batch, cfg.seq_len + 1))
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int64)
        toks[:, 0] = rng.choice(v, size=cfg.global_batch, p=probs)
        for t in range(1, cfg.seq_len + 1):
            follow = b[:, t] < 0.7
            toks[:, t] = np.where(follow, succ[toks[:, t - 1]],
                                  rng.choice(v, size=cfg.global_batch, p=probs))
        yield toks


def _file_stream(cfg: DataConfig) -> Iterator[np.ndarray]:
    """Flat binary (np.uint16/uint32 tokens) corpus, wrapped cyclically."""
    data = np.fromfile(cfg.path, dtype=np.uint16).astype(np.int64)
    if data.size < cfg.seq_len + 1:
        raise ValueError(f"corpus {cfg.path} too small: {data.size} tokens")
    rng = np.random.default_rng(cfg.seed)
    n = data.size - cfg.seq_len - 1
    while True:
        starts = rng.integers(0, n, size=cfg.global_batch)
        yield np.stack([data[s:s + cfg.seq_len + 1] for s in starts])


def batches(cfg: DataConfig, *, mesh: Optional[jax.sharding.Mesh] = None,
            batch_spec=None) -> Iterator[dict]:
    """Yields {"tokens": (B, S), "labels": (B, S)} jax arrays.

    With ``mesh``, the global batch is built with
    ``jax.make_array_from_process_local_data`` over the per-host stripe so
    the pipeline works unchanged on a real multi-host pod.
    """
    stream = _file_stream(cfg) if cfg.path else _synthetic_stream(cfg)
    nproc = jax.process_count()
    pidx = jax.process_index()
    per_host = cfg.global_batch // nproc

    for toks in stream:
        local = toks[pidx * per_host:(pidx + 1) * per_host]
        tokens = local[:, :-1].astype(np.int32)
        labels = local[:, 1:].astype(np.int32)
        if mesh is not None and batch_spec is not None:
            sh = jax.sharding.NamedSharding(mesh, batch_spec)
            yield {
                "tokens": jax.make_array_from_process_local_data(sh, tokens),
                "labels": jax.make_array_from_process_local_data(sh, labels),
            }
        else:
            yield {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
