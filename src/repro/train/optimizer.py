"""Pure-JAX AdamW with cosine schedule and global-norm clipping.

No optax dependency: state is a plain pytree ``{"m", "v", "step"}`` so it
shards with the same PartitionSpecs as the params (m/v inherit the param
spec; step is replicated).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_state(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(param_specs: Any) -> dict:
    """Optimizer-state PartitionSpecs mirroring the param specs."""
    from jax.sharding import PartitionSpec as P

    return {"m": param_specs, "v": param_specs, "step": P()}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: dict) -> tuple[Any, dict]:
    """One AdamW step. Returns (new_params, new_state)."""
    step = state["step"] + 1
    b1, b2 = cfg.betas

    if cfg.clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = cosine_lr(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (
            step_dir + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "step": step})
