"""npz-based checkpointing (no orbax dependency).

Pytrees are flattened to ``path/sep/arated/keys`` -> arrays.  Two restore
paths:

* ``restore(path, template)`` — rebuild into the structure of a template
  pytree (shapes must match); the historical training-loop path.
* ``load(path)`` — template-free: ``save`` embeds a JSON schema of the
  tree (dict nesting, ``PlannedPair``/``QuantizedLinear`` static fields,
  ``None`` markers) under the reserved ``__tree__`` key, so quantized
  deployment plans — packed uint32 weights, perms, scales, and the static
  scheme/group_size/kind fields — round-trip without re-running any
  quantization.  This is what ``plan/artifact.py`` serves from.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "||"
_TREE_KEY = "__tree__"
_SCHEMA_VERSION = 1


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def flatten_keys(tree: Any) -> dict[str, Any]:
    """Public ``{checkpoint key: leaf}`` view of a pytree (leaves NOT
    converted to numpy) — the key naming ``save``/``load`` use, so callers
    (the plan artifact's shard manifest) can address leaves stably."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_SEP.join(_path_str(p) for p in path)] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


# ---------------------------------------------------------------------------
# tree schema (template-free load)
# ---------------------------------------------------------------------------

def _schema(node: Any) -> dict:
    """JSON-serializable structure descriptor for the trees this repo
    checkpoints: nested dicts, the quantized-plan dataclasses, arrays."""
    from repro.core.quantization import QuantizedLinear
    from repro.core.reorder import PlannedPair

    if node is None:
        return {"t": "none"}
    if isinstance(node, QuantizedLinear):
        return {"t": "qlinear", "group_size": int(node.group_size),
                "kind": node.kind,
                "fields": {f: _schema(getattr(node, f))
                           for f in ("qweight", "scales", "zeros", "g_idx")}}
    if isinstance(node, PlannedPair):
        return {"t": "pair", "scheme": node.scheme,
                "fields": {f: _schema(getattr(node, f))
                           for f in ("up", "gate", "down", "p1_up",
                                     "p1_gate", "p2")}}
    if isinstance(node, dict):
        return {"t": "dict", "keys": {str(k): _schema(v)
                                      for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"t": "list" if isinstance(node, list) else "tuple",
                "items": [_schema(v) for v in node]}
    arr = np.asarray(node)
    return {"t": "array", "dtype": str(arr.dtype), "shape": list(arr.shape)}


def _from_schema(schema: dict, leaves: dict[str, np.ndarray],
                 prefix: tuple[str, ...] = ()) -> Any:
    from repro.core.quantization import QuantizedLinear
    from repro.core.reorder import PlannedPair

    t = schema["t"]
    if t == "none":
        return None
    if t == "qlinear":
        f = {k: _from_schema(v, leaves, prefix + (k,))
             for k, v in schema["fields"].items()}
        return QuantizedLinear(group_size=schema["group_size"],
                               kind=schema["kind"], **f)
    if t == "pair":
        f = {k: _from_schema(v, leaves, prefix + (k,))
             for k, v in schema["fields"].items()}
        return PlannedPair(scheme=schema["scheme"], **f)
    if t == "dict":
        return {k: _from_schema(v, leaves, prefix + (k,))
                for k, v in schema["keys"].items()}
    if t in ("list", "tuple"):
        items = [_from_schema(v, leaves, prefix + (str(i),))
                 for i, v in enumerate(schema["items"])]
        return items if t == "list" else tuple(items)
    key = _SEP.join(prefix)
    if key not in leaves:
        raise KeyError(f"checkpoint missing leaf {key}")
    return jnp.asarray(leaves[key], dtype=schema["dtype"])


def save(path: str, tree: Any, *, step: int | None = None) -> str:
    """Save pytree to ``path`` (.npz).  Returns the file written."""
    if step is not None:
        root, ext = os.path.splitext(path)
        path = f"{root}_step{step:08d}{ext or '.npz'}"
    if not path.endswith(".npz"):
        path += ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if _TREE_KEY in flat:
        raise ValueError(f"pytree key collides with reserved {_TREE_KEY!r}")
    meta = json.dumps({"version": _SCHEMA_VERSION, "tree": _schema(tree)})
    np.savez(path, **flat, **{_TREE_KEY: np.asarray(meta)})
    return path


def load(path: str) -> Any:
    """Template-free restore: rebuild the exact saved pytree — including
    quantized-plan statics (scheme / group_size / kind) — from the schema
    ``save`` embedded.  Raises on checkpoints written before the schema
    existed (use ``restore`` with a template for those)."""
    with np.load(path) as data:
        if _TREE_KEY not in data:
            raise ValueError(
                f"checkpoint {path} has no embedded tree schema; "
                "restore(path, template) is required for legacy files")
        meta = json.loads(str(data[_TREE_KEY][()]))
        if meta["version"] != _SCHEMA_VERSION:
            raise ValueError(
                f"checkpoint {path} schema v{meta['version']} != "
                f"supported v{_SCHEMA_VERSION}")
        leaves = {k: data[k] for k in data.files if k != _TREE_KEY}
    return _from_schema(meta["tree"], leaves)


def restore(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with np.load(path) as data:
        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for kpath, leaf in leaves_t:
            key = _SEP.join(_path_str(p) for p in kpath)
            if key not in data:
                raise KeyError(f"checkpoint {path} missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != template "
                    f"{leaf.shape}")
            out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def latest(dirpath: str, prefix: str) -> str | None:
    """Newest ``<prefix>_stepNNNNNNNN.npz`` in ``dirpath``."""
    if not os.path.isdir(dirpath):
        return None
    pat = re.compile(re.escape(prefix) + r"_step(\d+)\.npz$")
    best, best_step = None, -1
    for f in os.listdir(dirpath):
        m = pat.match(f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(dirpath, f), int(m.group(1))
    return best
