"""npz-based checkpointing (no orbax dependency).

Pytrees are flattened to ``path/sep/arated/keys`` -> arrays.  Static
dataclass fields (QuantizedLinear.kind etc.) are reconstructed from the
template pytree on restore, so quantized deployment plans round-trip.
"""

from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "||"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, *, step: int | None = None) -> str:
    """Save pytree to ``path`` (.npz).  Returns the file written."""
    if step is not None:
        root, ext = os.path.splitext(path)
        path = f"{root}_step{step:08d}{ext or '.npz'}"
    if not path.endswith(".npz"):
        path += ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))
    return path


def restore(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with np.load(path) as data:
        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for kpath, leaf in leaves_t:
            key = _SEP.join(_path_str(p) for p in kpath)
            if key not in data:
                raise KeyError(f"checkpoint {path} missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != template "
                    f"{leaf.shape}")
            out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def latest(dirpath: str, prefix: str) -> str | None:
    """Newest ``<prefix>_stepNNNNNNNN.npz`` in ``dirpath``."""
    if not os.path.isdir(dirpath):
        return None
    pat = re.compile(re.escape(prefix) + r"_step(\d+)\.npz$")
    best, best_step = None, -1
    for f in os.listdir(dirpath):
        m = pat.match(f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(dirpath, f), int(m.group(1))
    return best
