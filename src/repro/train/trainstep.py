"""Causal-LM loss and the jit-able train step factory.

Training runs on the *dense* (unquantized) model: GPTQ int4 weights are an
inference deployment artifact (the paper's subject), produced afterwards by
``repro.quant.gptq.quantize_model``.  Configs used for training therefore
carry ``quant.mode == "none"``.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParallelContext
from repro.models.registry import Model
from repro.train import optimizer as opt


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -1) -> jax.Array:
    """Mean token cross-entropy.  logits: (B, S, V), labels: (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(model: Model, params, batch, ctx: ParallelContext,
            *, window=None) -> jax.Array:
    logits = model.forward(params, batch, ctx, window=window)
    return cross_entropy(logits[:, :-1], batch["labels"][:, :-1])


def make_train_step(model: Model, ctx: ParallelContext,
                    ocfg: opt.AdamWConfig, *, window=None):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``state = {"params", "opt"}``; donate it at the jit call site
    (``donate_argnums=0``) so param buffers are reused in place.
    """

    def train_step(state, batch):
        def lf(p):
            return loss_fn(model, p, batch, ctx, window=window)

        loss, grads = jax.value_and_grad(lf)(state["params"])
        params, ostate = opt.apply_updates(ocfg, state["params"], grads,
                                           state["opt"])
        metrics = {
            "loss": loss,
            "grad_norm": opt.global_norm(grads),
            "lr": opt.cosine_lr(ocfg, ostate["step"]),
            "step": ostate["step"],
        }
        return {"params": params, "opt": ostate}, metrics

    return train_step


def init_train_state(model: Model, rng) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": opt.init_state(params)}


def train_state_specs(model: Model, params, ctx: ParallelContext) -> dict:
    pspecs = model.param_specs(params, ctx)
    return {"params": pspecs, "opt": opt.state_specs(pspecs)}
