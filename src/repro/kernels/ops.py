"""Public jit'd wrappers around the Pallas dequant kernels.

Handles the impedance between model code and kernel constraints:
* arbitrary leading batch dims (flattened to M),
* M/N padding to tile multiples (zero-padded, sliced off),
* dispatch on ``QuantizedLinear.kind`` (ordered vs g_idx gather),
* interpret=True on CPU (this container), compiled Mosaic on real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantization import PACK, QuantizedLinear
from repro.kernels import dequant_matmul as dk


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("compute_dtype", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def dequant_matmul(
    x: jax.Array,
    ql: QuantizedLinear,
    *,
    compute_dtype=jnp.float32,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``x @ dequantize(ql)`` with the fused Pallas kernel.

    ``x``: (..., K).  Returns (..., N) in ``compute_dtype``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    *lead, k = x.shape
    if k != ql.k:
        raise ValueError(f"x K={k} != weight K={ql.k}")
    n = ql.n
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)

    bm = min(block_m, max(8, m))
    x2 = _pad_to(x2, bm, 0)
    bn = min(block_n, n)
    qweight, scales, zeros = ql.qweight, ql.scales, ql.zeros
    if n % bn:
        qweight = _pad_to(qweight, bn, 1)
        scales = _pad_to(scales, bn, 1)
        zeros = _pad_to(zeros, bn, 1)

    bk_kw = {} if block_k is None else {"block_k": block_k}
    if ql.kind == "ordered":
        y = dk.dequant_matmul_ordered(
            x2, qweight, scales, zeros, group_size=ql.group_size,
            block_m=bm, block_n=bn, compute_dtype=compute_dtype,
            interpret=interpret, **bk_kw)
    else:
        y = dk.dequant_matmul_gidx(
            x2, qweight, scales, zeros, ql.g_idx,
            block_m=bm, block_n=bn, compute_dtype=compute_dtype,
            interpret=interpret, **bk_kw)
    return y[:m, :n].reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("tp", "wire_bits", "wire_block",
                                             "compute_dtype", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def dequant_matmul_wire(
    x: jax.Array,
    ql: QuantizedLinear,
    *,
    tp: int,
    wire_bits: int,
    wire_block: int,
    compute_dtype=jnp.float32,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int | None = None,
    interpret: bool | None = None,
):
    """Fused GEMM + blockwise wire quantize (DESIGN.md §10).

    ``x``: (..., K).  Returns the FLAT wire tuple over the ring-padded
    width ``n_pad`` (see ``comm/wire.wire_params``): ``(payload, scales,
    zeros-or-None)`` with shapes ``(..., n_pad)`` int8 / ``(..., n_pad //
    8)`` uint32 packed, and ``(..., n_pad // block)`` f16 — bit-identical
    to blockwise-quantizing the zero-padded dense kernel output.
    ``wire_block`` is the spec's PREFERRED block; the block actually used
    is ``choose_group_size(n_pad // tp, wire_block)``, exactly as the
    unfused collective picks it.
    """
    from repro.comm.wire import wire_params

    if interpret is None:
        interpret = not _on_tpu()
    if ql.kind != "ordered":
        raise ValueError(f"wire kernel needs the ordered layout, "
                         f"got {ql.kind!r}")
    *lead, k = x.shape
    if k != ql.k:
        raise ValueError(f"x K={k} != weight K={ql.k}")
    n = ql.n
    n_pad, _, bs = wire_params(n, tp, wire_bits, wire_block)
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)
    bm = min(block_m, max(8, m))
    x2 = _pad_to(x2, bm, 0)

    qweight, scales, zeros = ql.qweight, ql.scales, ql.zeros
    if n_pad != n:
        widths = [(0, 0), (0, n_pad - n)]
        qweight = jnp.pad(qweight, widths)
        # zero-padded SCALES make the padded columns dequantize to an
        # exact 0.0 — the same zeros the unfused path pads y_partial with.
        scales = jnp.pad(scales, widths)
        zeros = jnp.pad(zeros, widths)

    out = dk.dequant_matmul_wire_ordered(
        x2, qweight, scales, zeros, group_size=ql.group_size,
        wire_block=bs, wire_bits=wire_bits, block_m=bm, block_n=block_n,
        block_k=block_k, compute_dtype=compute_dtype, interpret=interpret)
    if wire_bits == 8:
        p, s = out
        return (p[:m].reshape(*lead, n_pad),
                s[:m].reshape(*lead, n_pad // bs), None)
    p, s, z = out
    return (p[:m].reshape(*lead, n_pad // PACK),
            s[:m].reshape(*lead, n_pad // bs),
            z[:m].reshape(*lead, n_pad // bs))


def pallas_dequant_matmul_ordered(x, ql, *, compute_dtype=jnp.float32,
                                  block_m: int = 128, block_n: int = 128,
                                  block_k: int | None = None,
                                  interpret: bool | None = None):
    """Algorithm-1 (ordered-groups) fused kernel; dispatch-registry entry
    for ``("ordered", "pallas")`` — see ``kernels/dispatch.py``."""
    if ql.kind != "ordered":
        raise ValueError(f"ordered kernel got layout kind {ql.kind!r}")
    return dequant_matmul(x, ql, compute_dtype=compute_dtype,
                          block_m=block_m, block_n=block_n,
                          block_k=block_k, interpret=interpret)


def pallas_dequant_matmul_gidx(x, ql, *, compute_dtype=jnp.float32,
                               block_m: int = 128, block_n: int = 128,
                               block_k: int | None = None,
                               interpret: bool | None = None):
    """Naive g_idx-gather fused kernel; dispatch-registry entry for
    ``("naive", "pallas")``."""
    if ql.kind != "naive":
        raise ValueError(f"g_idx kernel got layout kind {ql.kind!r}")
    return dequant_matmul(x, ql, compute_dtype=compute_dtype,
                          block_m=block_m, block_n=block_n,
                          block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequantize(ql: QuantizedLinear, *, out_dtype=jnp.float32,
               interpret: bool | None = None) -> jax.Array:
    """Materialize the fp weight with the standalone dequant kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    if ql.kind != "ordered":
        # unordered materialization has no locality to exploit; use ref path
        from repro.kernels import ref

        return ref.dequantize(ql).astype(out_dtype)
    return dk.dequantize_ordered(
        ql.qweight, ql.scales, ql.zeros, group_size=ql.group_size,
        out_dtype=out_dtype, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Fused flash attention (B, H, S, D); see kernels/flash_attention.py."""
    from repro.kernels import flash_attention as fa

    if interpret is None:
        interpret = not _on_tpu()
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
