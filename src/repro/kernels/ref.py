"""Pure-jnp oracles for the Pallas kernels (the `ref.py` contract).

These mirror the kernel APIs 1:1 and are the ground truth for the
shape/dtype sweep tests in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization as qz
from repro.core.quantization import QuantizedLinear


def dequantize(ql: QuantizedLinear, dtype=jnp.float32) -> jax.Array:
    return qz.dequantize(ql, dtype=dtype)


def dequant_matmul(x: jax.Array, ql: QuantizedLinear,
                   compute_dtype=jnp.float32) -> jax.Array:
    w = qz.dequantize(ql, dtype=compute_dtype)
    return jnp.matmul(x.astype(compute_dtype), w)


def dequant_matmul_ordered(x, qweight, scales, zeros, *, group_size,
                           compute_dtype=jnp.float32):
    k = qweight.shape[0] * qz.PACK
    q = qz.unpack_int4(qweight).astype(jnp.float32)
    g_idx = jnp.arange(k, dtype=jnp.int32) // group_size
    s = jnp.take(scales, g_idx, axis=0).astype(jnp.float32)
    z = jnp.take(zeros, g_idx, axis=0).astype(jnp.float32)
    w = ((q - z) * s).astype(compute_dtype)
    return jnp.matmul(x.astype(compute_dtype), w)


def dequant_matmul_gidx(x, qweight, scales, zeros, g_idx, *,
                        compute_dtype=jnp.float32):
    q = qz.unpack_int4(qweight).astype(jnp.float32)
    s = jnp.take(scales, g_idx, axis=0).astype(jnp.float32)
    z = jnp.take(zeros, g_idx, axis=0).astype(jnp.float32)
    w = ((q - z) * s).astype(compute_dtype)
    return jnp.matmul(x.astype(compute_dtype), w)


def flash_attention(q, k, v, *, causal=True, window=None):
    """Oracle for kernels.flash_attention: plain masked softmax attention.

    q/k/v: (B, H, S|T, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    t = k.shape[2]
    sc = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) / d ** 0.5
    i = jnp.arange(s)[:, None]
    j = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = mask & (j <= i)
    if window is not None:
        mask = mask & (j > i - window)
    sc = jnp.where(mask, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
