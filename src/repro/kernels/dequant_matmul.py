"""Pallas TPU kernels: fused int4 dequantize + GEMM.

TPU adaptation of the ExllamaV2 dequant GEMM (see DESIGN.md §2).  The unit
of locality on GPU is a warp's shared-memory staging of scales; on TPU it is
the VMEM residency of a ``(bk/gs, bn)`` metadata tile that is reused across
the whole ``(bm, bn)`` output tile.

Two variants, structurally mirroring the paper's two memory-access regimes:

* ``ordered`` — Algorithm-1 layout: quant groups are contiguous along K, so
  the K-block of size ``bk`` (a multiple of ``group_size``) touches exactly
  ``bk/gs`` metadata rows, streamed as a small VMEM tile.  This is the
  locality-friendly path.
* ``gidx`` — the naive Eq.-3 layout: rows belong to arbitrary groups, so the
  *entire* ``(G, bn)`` scale/zero table must stay VMEM-resident per N-tile
  and every row performs a dynamic gather.  This reproduces (structurally)
  the metadata-reload penalty the paper describes.

Packing: 8 int4 nibbles per uint32 along K (``quantization.pack_int4``); a
``(bk, bn)`` logical weight tile is a ``(bk/8, bn)`` uint32 VMEM tile,
unpacked with VPU shifts/masks and fed to the MXU in the compute dtype with
f32 accumulation.

All kernels are validated on CPU with ``interpret=True`` against
``ref.py``; on real TPUs the same ``pallas_call`` lowers to Mosaic.
"""

from __future__ import annotations

import functools
from math import gcd

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PACK = 8


def _lcm(a: int, b: int) -> int:
    return a * b // gcd(a, b)


def pick_block_k(k: int, group_size: int, target: int = 256) -> int:
    """K-tile: a multiple of lcm(group_size, 8) dividing K, close to target."""
    base = _lcm(group_size, PACK)
    bk = base
    while bk * 2 <= min(k, target) and k % (bk * 2) == 0:
        bk *= 2
    if k % bk:
        raise ValueError(f"K={k} not tileable with group_size={group_size}")
    return bk


# ---------------------------------------------------------------------------
# ordered-groups kernel
# ---------------------------------------------------------------------------

def _ordered_gemm_step(x_ref, qw_ref, s_ref, z_ref, acc_ref, *,
                       group_size: int, bk: int, compute_dtype):
    """One K-step of the ordered dequant-GEMM: unpack + dequant one
    ``(bk, bn)`` weight tile and accumulate into the f32 scratch.  Shared
    by the dense and the fused-wire-epilogue kernels so both produce
    bit-identical accumulator contents."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # unpack (bk/8, bn) uint32 -> (bk, bn) int in [0, 15]
    qw = qw_ref[...]
    shifts = (jnp.arange(PACK, dtype=jnp.uint32) * 4)[None, :, None]
    nibbles = (qw[:, None, :] >> shifts) & jnp.uint32(0xF)
    q = nibbles.reshape(bk, qw.shape[-1]).astype(jnp.float32)

    # one metadata row per quant group in this K-tile (VMEM-resident, reused
    # across the whole (bm, bn) tile — the TPU form of the locality win)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0) // group_size
    s = jnp.take_along_axis(s_ref[...].astype(jnp.float32), rows, axis=0)
    z = jnp.take_along_axis(z_ref[...].astype(jnp.float32), rows, axis=0)
    w = ((q - z) * s).astype(compute_dtype)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(compute_dtype), w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _dequant_matmul_ordered_kernel(x_ref, qw_ref, s_ref, z_ref, o_ref,
                                   acc_ref, *, group_size: int, bk: int,
                                   compute_dtype):
    """Grid (M/bm, N/bn, K/bk); K innermost so acc_ref carries the sum."""
    _ordered_gemm_step(x_ref, qw_ref, s_ref, z_ref, acc_ref,
                       group_size=group_size, bk=bk,
                       compute_dtype=compute_dtype)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dequant_matmul_ordered(
    x: jax.Array,           # (M, K)
    qweight: jax.Array,     # (K//8, N) uint32
    scales: jax.Array,      # (G, N)
    zeros: jax.Array,       # (G, N)
    *,
    group_size: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int | None = None,
    compute_dtype=jnp.float32,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    n = qweight.shape[1]
    bk = block_k or pick_block_k(k, group_size)
    bm = min(block_m, m)
    bn = min(block_n, n)
    if m % bm or n % bn or k % bk or bk % group_size:
        raise ValueError(f"bad tiling m={m},n={n},k={k} bm={bm},bn={bn},bk={bk}")
    out_dtype = out_dtype or compute_dtype

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(
        _dequant_matmul_ordered_kernel, group_size=group_size, bk=bk,
        compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // PACK, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, qweight, scales, zeros)


# ---------------------------------------------------------------------------
# fused wire-epilogue kernels (ordered layout only, DESIGN.md §10)
#
# The quantized collectives (comm/dispatch quant-int8/int4) re-read the
# dense GEMM output from HBM just to blockwise-quantize it onto the wire.
# These variants emit the wire payload (+f16 scales[/zeros]) DIRECTLY from
# the f32 accumulator tile at the last K step — y_partial never exists in
# HBM.  The quantize math replicates comm/dispatch._blockwise_quantize /
# _blockwise_quantize_int4 operation-for-operation so the payload is
# bit-identical to quantize(dense-kernel output).
# ---------------------------------------------------------------------------

def _dequant_matmul_wire8_kernel(x_ref, qw_ref, s_ref, z_ref, p_ref, ws_ref,
                                 acc_ref, *, group_size: int, bk: int,
                                 wire_block: int, compute_dtype, out_dtype):
    """Dense kernel's GEMM + symmetric-int8 wire quantize of the output
    tile: ``p_ref`` (bm, bn) int8 payload, ``ws_ref`` (bm, bn/wire_block)
    f16 scales."""
    _ordered_gemm_step(x_ref, qw_ref, s_ref, z_ref, acc_ref,
                       group_size=group_size, bk=bk,
                       compute_dtype=compute_dtype)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        # match the unfused dtype chain: kernel output in out_dtype, then
        # the collective's f32 upcast — required for bit-identity.
        y = acc_ref[...].astype(out_dtype).astype(jnp.float32)
        bm, bn = y.shape
        vb = y.reshape(bm, bn // wire_block, wire_block)
        s = jnp.max(jnp.abs(vb), axis=-1) / 127.0
        s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny)
        q = jnp.clip(jnp.round(vb / s[..., None]), -127, 127)
        p_ref[...] = q.reshape(bm, bn).astype(jnp.int8)
        ws_ref[...] = s.astype(jnp.float16)


def _dequant_matmul_wire4_kernel(x_ref, qw_ref, s_ref, z_ref, p_ref, ws_ref,
                                 wz_ref, acc_ref, *, group_size: int, bk: int,
                                 wire_block: int, compute_dtype, out_dtype):
    """Dense kernel's GEMM + asymmetric-int4 wire quantize with in-kernel
    nibble packing (the weights' ``pack_int4`` layout: 8 values per
    uint32): ``p_ref`` (bm, bn/8) uint32, ``ws_ref``/``wz_ref``
    (bm, bn/wire_block) f16."""
    _ordered_gemm_step(x_ref, qw_ref, s_ref, z_ref, acc_ref,
                       group_size=group_size, bk=bk,
                       compute_dtype=compute_dtype)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        y = acc_ref[...].astype(out_dtype).astype(jnp.float32)
        bm, bn = y.shape
        vb = y.reshape(bm, bn // wire_block, wire_block)
        vmax = jnp.maximum(jnp.max(vb, axis=-1), 0.0)
        vmin = jnp.minimum(jnp.min(vb, axis=-1), 0.0)
        s = (vmax - vmin) / 15.0
        s = jnp.where(s <= 0, 1.0, s)
        z = jnp.clip(jnp.round(-vmin / s), 0, 15)
        q = jnp.clip(jnp.round(vb / s[..., None] + z[..., None]), 0, 15)
        q = q.reshape(bm, bn).astype(jnp.uint32)
        shifts = (jnp.arange(PACK, dtype=jnp.uint32) * 4)[None, None, :]
        p_ref[...] = jnp.sum(q.reshape(bm, bn // PACK, PACK) << shifts,
                             axis=-1, dtype=jnp.uint32)
        ws_ref[...] = s.astype(jnp.float16)
        wz_ref[...] = z.astype(jnp.float16)


def pick_block_wire(n: int, wire_block: int, wire_bits: int,
                    target: int = 128) -> int:
    """N-tile for the wire kernels: wire-quant blocks (and, for int4,
    packed uint32 words) must not straddle tiles, so bn is a multiple of
    ``wire_block`` (int8) / ``lcm(wire_block, 8)`` (int4) dividing N."""
    base = wire_block if wire_bits == 8 else _lcm(wire_block, PACK)
    if n % base:
        raise ValueError(
            f"N={n} not tileable with wire_block={wire_block} "
            f"(bits={wire_bits})")
    bn = base
    while bn * 2 <= min(n, target) and n % (bn * 2) == 0:
        bn *= 2
    return bn


def dequant_matmul_wire_ordered(
    x: jax.Array,           # (M, K)
    qweight: jax.Array,     # (K//8, N) uint32
    scales: jax.Array,      # (G, N)
    zeros: jax.Array,       # (G, N)
    *,
    group_size: int,
    wire_block: int,
    wire_bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int | None = None,
    compute_dtype=jnp.float32,
    out_dtype=None,
    interpret: bool = True,
):
    """Fused GEMM + wire quantize.  Returns the flat wire tuple:
    int8 -> ``(payload (M, N) int8, scales (M, N/wire_block) f16)``;
    int4 -> ``(payload (M, N/8) uint32, scales, zeros)``.  Bit-identical
    to ``_blockwise_quantize[_int4](dequant_matmul_ordered(...))``."""
    m, k = x.shape
    n = qweight.shape[1]
    if wire_bits not in (4, 8):
        raise ValueError(f"wire_bits must be 4 or 8, got {wire_bits}")
    bk = block_k or pick_block_k(k, group_size)
    bm = min(block_m, m)
    bn = pick_block_wire(n, wire_block, wire_bits, target=block_n)
    if m % bm or k % bk or bk % group_size:
        raise ValueError(f"bad tiling m={m},k={k} bm={bm},bk={bk}")
    out_dtype = out_dtype or compute_dtype

    grid = (m // bm, n // bn, k // bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk // PACK, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
    ]
    wb = bn // wire_block
    if wire_bits == 8:
        kernel = functools.partial(
            _dequant_matmul_wire8_kernel, group_size=group_size, bk=bk,
            wire_block=wire_block, compute_dtype=compute_dtype,
            out_dtype=out_dtype)
        out_shape = (jax.ShapeDtypeStruct((m, n), jnp.int8),
                     jax.ShapeDtypeStruct((m, n // wire_block), jnp.float16))
        out_specs = [pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
                     pl.BlockSpec((bm, wb), lambda i, j, kk: (i, j))]
    else:
        kernel = functools.partial(
            _dequant_matmul_wire4_kernel, group_size=group_size, bk=bk,
            wire_block=wire_block, compute_dtype=compute_dtype,
            out_dtype=out_dtype)
        out_shape = (jax.ShapeDtypeStruct((m, n // PACK), jnp.uint32),
                     jax.ShapeDtypeStruct((m, n // wire_block), jnp.float16),
                     jax.ShapeDtypeStruct((m, n // wire_block), jnp.float16))
        out_specs = [pl.BlockSpec((bm, bn // PACK), lambda i, j, kk: (i, j)),
                     pl.BlockSpec((bm, wb), lambda i, j, kk: (i, j)),
                     pl.BlockSpec((bm, wb), lambda i, j, kk: (i, j))]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, qweight, scales, zeros)


# ---------------------------------------------------------------------------
# unordered (g_idx gather) kernel — the naive-actorder path
# ---------------------------------------------------------------------------

def _dequant_matmul_gidx_kernel(g_ref, x_ref, qw_ref, s_ref, z_ref, o_ref,
                                acc_ref, *, bk: int, compute_dtype):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qw = qw_ref[...]
    shifts = (jnp.arange(PACK, dtype=jnp.uint32) * 4)[None, :, None]
    nibbles = (qw[:, None, :] >> shifts) & jnp.uint32(0xF)
    q = nibbles.reshape(bk, qw.shape[-1]).astype(jnp.float32)

    # per-row dynamic gather from the FULL (G, bn) metadata tile — the
    # locality penalty of the unordered layout, reproduced structurally.
    rows = g_ref[pl.dslice(kk * bk, bk)][:, None]
    s = jnp.take_along_axis(s_ref[...].astype(jnp.float32), rows, axis=0)
    z = jnp.take_along_axis(z_ref[...].astype(jnp.float32), rows, axis=0)
    w = ((q - z) * s).astype(compute_dtype)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(compute_dtype), w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dequant_matmul_gidx(
    x: jax.Array,           # (M, K)
    qweight: jax.Array,     # (K//8, N) uint32
    scales: jax.Array,      # (G, N)
    zeros: jax.Array,       # (G, N)
    g_idx: jax.Array,       # (K,) int32 — unordered group ids
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    compute_dtype=jnp.float32,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    n = qweight.shape[1]
    g = scales.shape[0]
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    while k % bk:
        bk //= 2
    if bk % PACK or m % bm or n % bn:
        raise ValueError(f"bad tiling m={m},n={n},k={k} bm={bm},bn={bn},bk={bk}")
    out_dtype = out_dtype or compute_dtype

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(
        _dequant_matmul_gidx_kernel, bk=bk, compute_dtype=compute_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # NB: with scalar prefetch, index maps get the prefetch ref too.
            pl.BlockSpec((bm, bk), lambda i, j, kk, g_ref: (i, kk)),
            pl.BlockSpec((bk // PACK, bn), lambda i, j, kk, g_ref: (kk, j)),
            pl.BlockSpec((g, bn), lambda i, j, kk, g_ref: (0, j)),  # FULL G
            pl.BlockSpec((g, bn), lambda i, j, kk, g_ref: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, g_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(g_idx, x, qweight, scales, zeros)


# ---------------------------------------------------------------------------
# standalone dequantize kernel (weight materialization, e.g. for conversion)
# ---------------------------------------------------------------------------

def _dequant_kernel(qw_ref, s_ref, z_ref, o_ref, *, group_size: int, bk: int):
    qw = qw_ref[...]
    shifts = (jnp.arange(PACK, dtype=jnp.uint32) * 4)[None, :, None]
    nibbles = (qw[:, None, :] >> shifts) & jnp.uint32(0xF)
    q = nibbles.reshape(bk, qw.shape[-1]).astype(jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0) // group_size
    s = jnp.take_along_axis(s_ref[...].astype(jnp.float32), rows, axis=0)
    z = jnp.take_along_axis(z_ref[...].astype(jnp.float32), rows, axis=0)
    o_ref[...] = ((q - z) * s).astype(o_ref.dtype)


def dequantize_ordered(
    qweight: jax.Array, scales: jax.Array, zeros: jax.Array, *,
    group_size: int, block_n: int = 256, block_k: int | None = None,
    out_dtype=jnp.float32, interpret: bool = True,
) -> jax.Array:
    k = qweight.shape[0] * PACK
    n = qweight.shape[1]
    bk = block_k or pick_block_k(k, group_size)
    bn = min(block_n, n)
    while bn > 1 and n % bn:
        bn //= 2
    if n % bn or k % bk:
        raise ValueError(f"bad tiling k={k},n={n} bk={bk},bn={bn}")
    kernel = functools.partial(_dequant_kernel, group_size=group_size, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((bk // PACK, bn), lambda kk, j: (kk, j)),
            pl.BlockSpec((bk // group_size, bn), lambda kk, j: (kk, j)),
            pl.BlockSpec((bk // group_size, bn), lambda kk, j: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda kk, j: (kk, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), out_dtype),
        interpret=interpret,
    )(qweight, scales, zeros)
