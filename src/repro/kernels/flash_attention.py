"""Pallas TPU flash attention (online-softmax, causal/windowed).

Why it's here: the dry-run roofline (EXPERIMENTS.md §Roofline) shows the
32k-prefill memory term dominated by S×T score-tile HBM round-trips —
unfused attention writes/reads the (S, T) f32 scores several times.  The
flash formulation keeps score tiles in VMEM and carries online-softmax
statistics across K-blocks, so HBM traffic drops to the q/k/v reads and
the output write (accounted analytically in §Perf — XLA's cost_analysis
cannot see inside a pallas_call).

Layout: q (B, H, S, D), k/v (B, H, T, D) — GQA callers repeat/broadcast KV
heads before the call (XLA fuses the broadcast into the DMA on TPU).

Grid: (B*H, S/bq, T/bk) with the K dimension innermost ("arbitrary"
semantics); VMEM scratch carries (acc, m, l) across K-blocks — the same
accumulator pattern as the dequant GEMM kernels.  Causal masking skips
whole blocks above the diagonal via pl.when (no wasted MXU work beyond
the diagonal block) and masks elementwise on the diagonal.

Validated on CPU with interpret=True against ``ref.flash_attention``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _compiler_params_cls():
    """Mosaic compiler-params class across jax generations (renamed from
    TPUCompilerParams on the 0.4.x line); fail loudly if neither exists."""
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise RuntimeError(
        "unsupported jax version: pallas TPU exposes neither "
        "CompilerParams nor TPUCompilerParams")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, scale: float, causal: bool,
                  window: Optional[int], seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = qi * bq
    k0 = ki * bk

    # causal block skip: the whole K-block is above the diagonal when its
    # first key index exceeds the last query index of this Q-block
    run = True
    if causal:
        run = k0 <= q0 + bq - 1
    if window is not None:
        run = jnp.logical_and(run, k0 + bk - 1 > q0 - window)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                # (bq, bk)

        iq = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ik = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (ik <= iq)
        if window is not None:
            mask = mask & (ik > iq - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        # rows with no valid keys (shouldn't happen causally) keep l=0;
        # guard the divide anyway.
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (B, H, S, D)
    k: jax.Array,            # (B, H, T, D)
    v: jax.Array,            # (B, H, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    t = k.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, t)
    if s % bq or t % bk:
        raise ValueError(f"S={s}/T={t} must tile by ({bq}, {bk})")
    scale = d ** -0.5

    bh = b * h
    q3 = q.reshape(bh, s, d)
    k3 = k.reshape(bh, t, d)
    v3 = v.reshape(bh, t, d)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
        window=window, seq_q=s, seq_k=t)
    out = pl.pallas_call(
        kernel,
        grid=(bh, s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        # CompilerParams was TPUCompilerParams on the jax 0.4.x line
        compiler_params=_compiler_params_cls()(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, h, s, d)


def hbm_traffic_bytes(b, h, s, t, d, *, dtype_bytes=2) -> dict:
    """Analytic HBM traffic of the flash kernel vs the unfused path.

    Flash: q,k,v read once per K-pass... on TPU the K-blocks re-stream k/v
    per Q-block: k/v read S/bq times; q and out touched once.
    Unfused: scores (S, T) f32 written+read ~3x (mask, softmax, av).
    """
    flash = (b * h * s * d * dtype_bytes          # q
             + 2 * b * h * t * d * dtype_bytes * (s // 128)  # k,v re-read
             + b * h * s * d * dtype_bytes)       # out
    unfused = (b * h * s * d * dtype_bytes * 2
               + 2 * b * h * t * d * dtype_bytes
               + 3 * b * h * s * t * 4)           # f32 score round-trips
    return {"flash": flash, "unfused": unfused,
            "ratio": unfused / max(flash, 1)}
