"""Kernel dispatch: ``(layout kind, backend)`` -> dequant-GEMM callable.

This registry is the ONLY place in the repo that maps backend names to
kernel implementations.  ``schemes.qmatmul`` (and therefore every scheme
forward, model MLP, and serving path) resolves its kernel here from the
``ExecutionPolicy.backend`` field; new backends register themselves with
the ``@register`` decorator and immediately become valid policy values —
no stringly-typed branching at the call sites.

Kernel contract: ``fn(x, ql, policy) -> y`` with ``x: (..., K)``,
``ql: QuantizedLinear`` (whose static ``kind`` selected the entry), and
``policy: ExecutionPolicy`` supplying dtypes and tiling.  Returns
``(..., N)`` in ``policy.compute_dtype``.

Seed entries (see DESIGN.md §1):

* ``ref``    — pure-jnp oracle (``kernels/ref.py``), both layouts.
* ``jnp``    — dequantize + ``jnp.matmul``; XLA fuses the dequant into the
  GEMM epilogue on TPU, and the dry-run lowers this path so cost_analysis
  sees real FLOPs/bytes.
* ``pallas`` — the fused kernels: Algorithm-1 ordered layout
  (``pallas-ordered``) and the naive g_idx gather (``pallas-gidx``).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as qz
from repro.core.policy import ExecutionPolicy
from repro.core.quantization import QuantizedLinear

KernelFn = Callable[[jax.Array, QuantizedLinear, ExecutionPolicy], jax.Array]

_REGISTRY: dict[tuple[str, str], KernelFn] = {}

KINDS = ("ordered", "naive")


def register(kind: str, backend: str):
    """Decorator: register ``fn(x, ql, policy)`` for a (kind, backend)."""
    if kind not in KINDS:
        raise ValueError(f"unknown layout kind {kind!r}, expected {KINDS}")

    def deco(fn: KernelFn) -> KernelFn:
        _REGISTRY[(kind, backend)] = fn
        return fn

    return deco


def backends(kind: Optional[str] = None) -> tuple[str, ...]:
    """Registered backend names (optionally restricted to one layout kind)."""
    return tuple(sorted({b for (k, b) in _REGISTRY
                         if kind is None or k == kind}))


def resolve(kind: str, backend: str) -> KernelFn:
    """Look up the kernel for a (layout kind, backend) pair."""
    try:
        return _REGISTRY[(kind, backend)]
    except KeyError:
        raise ValueError(
            f"no kernel registered for layout kind={kind!r} "
            f"backend={backend!r}; registered backends for this kind: "
            f"{list(backends(kind))}") from None


def qmatmul(x: jax.Array, ql: QuantizedLinear,
            policy: ExecutionPolicy) -> jax.Array:
    """``x @ dequantize(ql)`` via the policy-selected kernel."""
    return resolve(ql.kind, policy.backend)(x, ql, policy)


# ---------------------------------------------------------------------------
# seed entries
# ---------------------------------------------------------------------------

@register("ordered", "ref")
@register("naive", "ref")
def _ref_dequant_matmul(x, ql, policy):
    from repro.kernels import ref

    return ref.dequant_matmul(x, ql, compute_dtype=policy.compute_dtype)


@register("ordered", "jnp")
@register("naive", "jnp")
def _jnp_dequant_matmul(x, ql, policy):
    w = qz.dequantize(ql, dtype=policy.compute_dtype)
    return jnp.matmul(x.astype(policy.compute_dtype), w)


@register("ordered", "pallas")
def _pallas_ordered(x, ql, policy):
    from repro.kernels import ops

    t = policy.tiling
    return ops.pallas_dequant_matmul_ordered(
        x, ql, compute_dtype=policy.compute_dtype,
        block_m=t.block_m, block_n=t.block_n, block_k=t.block_k,
        interpret=t.interpret)


@register("naive", "pallas")
def _pallas_gidx(x, ql, policy):
    from repro.kernels import ops

    t = policy.tiling
    return ops.pallas_dequant_matmul_gidx(
        x, ql, compute_dtype=policy.compute_dtype,
        block_m=t.block_m, block_n=t.block_n, block_k=t.block_k,
        interpret=t.interpret)
