"""Kernel dispatch: ``(layout kind, backend)`` -> dequant-GEMM callable.

This registry is the ONLY place in the repo that maps backend names to
kernel implementations.  ``schemes.qmatmul`` (and therefore every scheme
forward, model MLP, and serving path) resolves its kernel here from the
``ExecutionPolicy.backend`` field; new backends register themselves with
the ``@register`` decorator and immediately become valid policy values —
no stringly-typed branching at the call sites.

Kernel contract: ``fn(x, ql, policy) -> y`` with ``x: (..., K)``,
``ql: QuantizedLinear`` (whose static ``kind`` selected the entry), and
``policy: ExecutionPolicy`` supplying dtypes and tiling.  Returns
``(..., N)`` in ``policy.compute_dtype``.

Seed entries (see DESIGN.md §1):

* ``ref``    — pure-jnp oracle (``kernels/ref.py``), both layouts.
* ``jnp``    — dequantize + ``jnp.matmul``; XLA fuses the dequant into the
  GEMM epilogue on TPU, and the dry-run lowers this path so cost_analysis
  sees real FLOPs/bytes.
* ``pallas`` — the fused kernels: Algorithm-1 ordered layout
  (``pallas-ordered``) and the naive g_idx gather (``pallas-gidx``).
* ``pallas-fused`` — the fused WIRE-epilogue kernel (ordered layout
  only, DESIGN.md §10): its output contract is the quantized-collective
  wire tuple ``(payload, scales[, zeros])``, not a dense ``y_partial``.
  It is never selected by ``ExecutionPolicy.backend``; the per-site
  ``CollectivePlan`` opts in via a ``:fused`` quant spec and
  ``schemes._pair_local_forward`` calls ``qmatmul_wire``.

The pallas entries degrade gracefully (the ``ExecutionPolicy.auto``
contract): when a site's K cannot tile the Pallas grid (``pick_block_k``
would raise), they fall back to the ``jnp`` kernel with a one-line
warning instead of erroring at forward time.
"""

from __future__ import annotations

import warnings
from math import gcd
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as qz
from repro.core.policy import ExecutionPolicy
from repro.core.quantization import PACK, QuantizedLinear

KernelFn = Callable[[jax.Array, QuantizedLinear, ExecutionPolicy], jax.Array]

_REGISTRY: dict[tuple[str, str], KernelFn] = {}

KINDS = ("ordered", "naive")

#: backends whose output is a wire tuple, not a dense (..., N) array —
#: resolvable via the same registry but excluded from ``qmatmul``.
WIRE_BACKENDS = ("pallas-fused",)


def register(kind: str, backend: str):
    """Decorator: register ``fn(x, ql, policy)`` for a (kind, backend)."""
    if kind not in KINDS:
        raise ValueError(f"unknown layout kind {kind!r}, expected {KINDS}")

    def deco(fn: KernelFn) -> KernelFn:
        _REGISTRY[(kind, backend)] = fn
        return fn

    return deco


def backends(kind: Optional[str] = None) -> tuple[str, ...]:
    """Registered backend names (optionally restricted to one layout kind)."""
    return tuple(sorted({b for (k, b) in _REGISTRY
                         if kind is None or k == kind}))


def resolve(kind: str, backend: str) -> KernelFn:
    """Look up the kernel for a (layout kind, backend) pair."""
    try:
        return _REGISTRY[(kind, backend)]
    except KeyError:
        raise ValueError(
            f"no kernel registered for layout kind={kind!r} "
            f"backend={backend!r}; registered backends for this kind: "
            f"{list(backends(kind))}") from None


def qmatmul(x: jax.Array, ql: QuantizedLinear,
            policy: ExecutionPolicy) -> jax.Array:
    """``x @ dequantize(ql)`` via the policy-selected kernel."""
    if policy.backend in WIRE_BACKENDS:
        raise ValueError(
            f"backend {policy.backend!r} emits a wire payload, not a dense "
            f"output; it is selected per site by a ':fused' collective spec "
            f"(CollectivePlan), not by ExecutionPolicy.backend")
    return resolve(ql.kind, policy.backend)(x, ql, policy)


def qmatmul_wire(x: jax.Array, ql: QuantizedLinear, policy: ExecutionPolicy,
                 *, spec, tp: int):
    """Fused GEMM + wire quantize -> ``comm.wire.WirePayload`` ready for
    ``comm.apply_wire`` (ring phase 1 starts from the kernel output).
    ``spec`` is the resolved quant-int8/int4 ``CollectiveSpec`` with
    ``fused=True``; caller guarantees ``supports_wire(ql, spec, tp)``."""
    from repro.comm.wire import WirePayload, wire_params

    payload, scales, zeros = resolve(ql.kind, "pallas-fused")(
        x, ql, policy, spec=spec, tp=tp)
    _, _, bs = wire_params(ql.n, tp, spec.bits, spec.block_size)
    return WirePayload(payload, scales, zeros, n=ql.n, tp=tp,
                       bits=spec.bits, block=bs,
                       out_dtype=policy.compute_dtype)


def supports_wire(ql: QuantizedLinear, spec, tp: int) -> bool:
    """True when the fused wire epilogue CAN serve this GEMM site: a
    quantized full-output collective, a real ring (``tp > 1``), the
    ordered layout, and a Pallas-tileable K.  The tuner uses this to
    decide whether to mark a chosen spec ``fused``; the runtime gate in
    ``schemes._pair_local_forward`` re-checks it (plus ``spec.fused``),
    so a compiled ``:fused`` plan never dies at forward time."""
    return wire_support(ql, spec, tp)[0]


def wire_support(ql: QuantizedLinear, spec, tp: int) -> tuple[bool, str]:
    """``supports_wire`` with the reason it fails — ``(True, "")`` when
    the wire kernel applies, else ``(False, why)``.  The reason string is
    shape/layout-derived (never trace-dependent), which is what
    ``schemes._warn_unfusable`` keys its once-per-(site, reason) cache
    on."""
    name = getattr(spec, "name", None)
    if name not in ("quant-int8", "quant-int4"):
        return False, f"collective {name!r} has no wire payload form"
    if tp <= 1:
        return False, "tp=1 (no ring to feed)"
    if ql.kind != "ordered" or ("ordered", "pallas-fused") not in _REGISTRY:
        return False, f"layout {ql.kind!r} has no wire-epilogue kernel"
    return _tileable(ql)


# ---------------------------------------------------------------------------
# graceful Pallas fallback (non-tileable K -> jnp with a one-line warning)
# ---------------------------------------------------------------------------

def _tileable(ql: QuantizedLinear) -> tuple[bool, str]:
    """Can the Pallas grid tile this layout's K?  Mirrors the constraints
    ``dequant_matmul.pick_block_k`` (ordered: K % lcm(group_size, 8)) and
    the g_idx kernel's power-of-two halving enforce."""
    if ql.kind == "ordered":
        base = ql.group_size * PACK // gcd(ql.group_size, PACK)
        if ql.k % base:
            return (False, f"K={ql.k} is not a multiple of "
                           f"lcm(group_size={ql.group_size}, {PACK})={base}")
    else:
        bk = min(256, ql.k)
        while bk > 1 and ql.k % bk:
            bk //= 2
        if ql.k % bk or bk % PACK:
            return (False, f"K={ql.k} has no power-of-two tile that is a "
                           f"multiple of {PACK}")
    return True, ""


_FALLBACK_WARNED: set = set()


def _warn_fallback(ql: QuantizedLinear, reason: str) -> None:
    key = (ql.kind, ql.k, ql.n, ql.group_size)
    if key in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(key)
    warnings.warn(
        f"pallas {ql.kind} kernel cannot tile this site ({reason}); "
        f"falling back to the jnp backend for K={ql.k}, N={ql.n}",
        stacklevel=3)


# ---------------------------------------------------------------------------
# seed entries
# ---------------------------------------------------------------------------

@register("ordered", "ref")
@register("naive", "ref")
def _ref_dequant_matmul(x, ql, policy):
    from repro.kernels import ref

    return ref.dequant_matmul(x, ql, compute_dtype=policy.compute_dtype)


@register("ordered", "jnp")
@register("naive", "jnp")
def _jnp_dequant_matmul(x, ql, policy):
    w = qz.dequantize(ql, dtype=policy.compute_dtype)
    return jnp.matmul(x.astype(policy.compute_dtype), w)


@register("ordered", "pallas")
def _pallas_ordered(x, ql, policy):
    from repro.kernels import ops

    ok, reason = _tileable(ql)
    if not ok:
        _warn_fallback(ql, reason)
        return _jnp_dequant_matmul(x, ql, policy)
    t = policy.tiling
    return ops.pallas_dequant_matmul_ordered(
        x, ql, compute_dtype=policy.compute_dtype,
        block_m=t.block_m, block_n=t.block_n, block_k=t.block_k,
        interpret=t.interpret)


@register("naive", "pallas")
def _pallas_gidx(x, ql, policy):
    from repro.kernels import ops

    ok, reason = _tileable(ql)
    if not ok:
        _warn_fallback(ql, reason)
        return _jnp_dequant_matmul(x, ql, policy)
    t = policy.tiling
    return ops.pallas_dequant_matmul_gidx(
        x, ql, compute_dtype=policy.compute_dtype,
        block_m=t.block_m, block_n=t.block_n, block_k=t.block_k,
        interpret=t.interpret)


@register("ordered", "pallas-fused")
def _pallas_fused_wire(x, ql, policy, *, spec, tp):
    """Wire-contract entry (DESIGN.md §10): returns ``(payload, scales,
    zeros-or-None)`` over the ring-padded width instead of a dense
    ``y_partial`` — use via ``qmatmul_wire``, never ``qmatmul``."""
    from repro.kernels import ops

    t = policy.tiling
    return ops.dequant_matmul_wire(
        x, ql, tp=tp, wire_bits=spec.bits, wire_block=spec.block_size,
        compute_dtype=policy.compute_dtype,
        block_m=t.block_m, block_n=t.block_n, block_k=t.block_k,
        interpret=t.interpret)
