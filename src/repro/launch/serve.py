"""Serving entrypoint: quantized deployment with the paper's schemes.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --scheme tp-aware --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.comm import CollectiveSpec, dispatch as comm_dispatch
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.policy import ExecutionPolicy
from repro.launch import mesh as mesh_lib
from repro.models.common import ParallelContext, REPLICATED
from repro.runtime.sampling import SamplingConfig
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.serve import make_engine


def _collective(value: str) -> str:
    """argparse type: validate against the comm registry, keep the string
    (the config stores the shorthand; the policy parses it once)."""
    try:
        CollectiveSpec.parse(value)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None
    return value


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--scheme", default="tp-aware",
                    choices=["naive-actorder", "exllama", "tp-aware"])
    ap.add_argument("--backend", default="auto",
                    help="dequant-GEMM kernel (auto | any backend "
                         "registered in kernels.dispatch)")
    ap.add_argument("--collective", default="psum", type=_collective,
                    help="row-TP epilogue collective spec; any strategy "
                         "registered in comm.dispatch: "
                         + ", ".join(comm_dispatch.strategies())
                         + " (parameterized shorthands like cast:float16 "
                           "or quant-int8:64 also accepted)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-budget", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    # the whole deployment plan lives on the config; the policy below is
    # derived from it and flows unchanged to the kernels
    cfg = cfg.with_quant(mode="mlp", scheme=args.scheme,
                         backend=args.backend, collective=args.collective)
    policy = ExecutionPolicy.from_config(cfg)

    if args.tp > 1:
        mesh = mesh_lib.make_host_mesh(model=args.tp)
        ctx = ParallelContext(mesh=mesh, batch_axes=("data",),
                              policy=policy)
    else:
        ctx = REPLICATED

    max_seq = args.prompt_budget + args.max_new + 1
    engine = make_engine(cfg, jax.random.PRNGKey(args.seed), ctx=ctx,
                         max_seq=max_seq, policy=policy)
    sched = Scheduler(engine, max_batch=args.max_batch,
                      prompt_budget=args.prompt_budget,
                      scfg=SamplingConfig(temperature=args.temperature,
                                          top_k=40),
                      seed=args.seed)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, args.prompt_budget))
        sched.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=args.max_new))
    done = sched.run()
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in done.values())
    for rid, r in sorted(done.items()):
        print(f"req {rid}: prompt {len(r.prompt):3d} -> {r.output[:8]}...")
    print(f"\n{len(done)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s) [scheme={args.scheme} "
          f"backend={policy.backend} "
          f"collective={policy.collective.shorthand()}]")


if __name__ == "__main__":
    main()
