"""Serving entrypoint: quantized deployment with the paper's schemes.

Three lifecycles:

* one-shot (compile in memory at startup):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --scheme tp-aware --requests 8

* prepare-once / serve-many (the paper's a-priori plan, made literal):

    PYTHONPATH=src python -m repro.launch.serve prepare \
        --arch qwen3-4b --smoke --scheme tp-aware --tp 2 --out /tmp/plan
    PYTHONPATH=src python -m repro.launch.serve --artifact /tmp/plan \
        --tp 2 --requests 8

  ``prepare`` runs the offline plan compiler (quantize -> reorder/fold ->
  TP pre-shard) and writes a ``DeploymentArtifact``; serving from it
  never invokes GPTQ or the layout planner — the manifest is validated
  against the reconstructed config/policy/mesh so a stale or mismatched
  plan refuses to serve instead of silently computing the wrong thing.

* network front end (``repro.serving``, DESIGN.md §8) — instead of the
  built-in synthetic request batch, expose the engine over HTTP/SSE:

    PYTHONPATH=src python -m repro.launch.serve --artifact /tmp/plan \
        --tp 2 --http :8100
    curl -N localhost:8100/v1/generate -d '{"text": "hi", \
        "max_new_tokens": 8}'

  Ctrl-C drains: the admission queue closes (new requests get 503),
  in-flight requests finish, then the server exits.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.comm import (CollectivePlan, dispatch as comm_dispatch,
                        parse_collective)
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.policy import ExecutionPolicy
from repro.dist import MeshPlan
from repro.launch import mesh as mesh_lib
from repro.models.common import ParallelContext, REPLICATED
from repro.runtime.sampling import SamplingConfig
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.serve import make_engine


def _mesh_plan(value: str) -> MeshPlan:
    """argparse type: a ``dp2xtp4``-style device-grid shorthand."""
    try:
        return MeshPlan.parse(value)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None


def _dist_args(ap: argparse.ArgumentParser):
    """Multi-process launch flags (DESIGN.md §11): every process runs the
    same command with its own ``--process-id``."""
    ap.add_argument("--mesh", type=_mesh_plan, default=None,
                    help="device-grid plan, e.g. dp1xtp2 (axes data x "
                         "model over ALL processes' devices); implies "
                         "per-rank artifact loading — each process reads "
                         "only its own rank_NN.npz shards")
    ap.add_argument("--coordinator", default="127.0.0.1:9911",
                    help="host:port of process 0 (multi-process launch)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)


def _collective(value: str) -> str:
    """argparse type: validate against the comm registry, keep the string
    (the config stores the shorthand; the policy parses it once).
    Accepts bare specs and per-layer plans alike."""
    try:
        parse_collective(value)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None
    return value


def _plan_args(ap: argparse.ArgumentParser):
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--scheme", default="tp-aware",
                    choices=["naive-actorder", "exllama", "tp-aware"])
    ap.add_argument("--backend", default="auto",
                    help="dequant-GEMM kernel (auto | any backend "
                         "registered in kernels.dispatch)")
    ap.add_argument("--collective", default="psum", type=_collective,
                    help="row-TP epilogue collective spec; any strategy "
                         "registered in comm.dispatch: "
                         + ", ".join(comm_dispatch.strategies())
                         + " (parameterized shorthands like cast:float16, "
                           "quant-int8:64 or quant-int4:32 also accepted), "
                           "or a per-layer plan 'per-layer:<glob>=<spec>"
                           ",...,*=<default>' (e.g. per-layer:*.mlp="
                           "quant-int8:128,*=psum)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-page-size", type=int, default=None,
                    help="turn on the paged KV cache with this page size "
                         "in tokens (DESIGN.md §9); default: dense "
                         "per-slot rows")
    ap.add_argument("--kv-bits", type=int, default=None, choices=[8, 4],
                    help="quantize page payloads blockwise to int8/int4 "
                         "(requires --kv-page-size)")


def _build_cfg(args):
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    # the whole deployment plan lives on the config; the policy below is
    # derived from it and flows unchanged to the kernels
    return cfg.with_quant(mode="mlp", scheme=args.scheme,
                          backend=args.backend, collective=args.collective,
                          kv_page_size=args.kv_page_size,
                          kv_bits=args.kv_bits)


def prepare(argv=None):
    """Offline compile: write a ``DeploymentArtifact`` directory."""
    from repro.plan import compiler

    ap = argparse.ArgumentParser(prog="repro.launch.serve prepare")
    _plan_args(ap)
    ap.add_argument("--tp", type=int, default=1,
                    help="target model-axis degree the shards are pre-"
                         "split for (serving must use the same)")
    ap.add_argument("--out", required=True, help="artifact directory")
    ap.add_argument("--autotune-collectives", action="store_true",
                    help="score every full-output collective per pair "
                         "site (analytic wire bytes + calibration error "
                         "probe; plan/tuner.py) and compile the chosen "
                         "per-layer CollectivePlan into the artifact "
                         "(overrides --collective's epilogue choice)")
    ap.add_argument("--tune-budget", type=float, default=None,
                    help="max relative activation error a tuned "
                         "collective may introduce (default: the "
                         "tuner's DEFAULT_BUDGET, 0.05)")
    ap.add_argument("--overlap-collectives", action="store_true",
                    help="mark tuned quantized epilogues ':overlap' — "
                         "the serve-time ring is decomposed into "
                         "ppermute rotations pipelined against the next "
                         "microbatch's dequant-GEMM (bit-identical; "
                         "requires --autotune-collectives)")
    args = ap.parse_args(argv)
    if args.overlap_collectives and not args.autotune_collectives:
        ap.error("--overlap-collectives requires --autotune-collectives")

    cfg = _build_cfg(args)
    # record the intended grid in the manifest (provenance: validate pins
    # only the TP degree, so serving may widen dp without re-preparing)
    policy = ExecutionPolicy.from_config(cfg).with_(
        mesh=MeshPlan(dp=1, tp=args.tp))
    t0 = time.time()
    art = compiler.prepare(cfg, tp=args.tp, seed=args.seed, policy=policy,
                           extra_manifest={"smoke": bool(args.smoke)},
                           autotune=args.autotune_collectives,
                           tune_budget=args.tune_budget,
                           tune_overlap=args.overlap_collectives)
    path = art.save(args.out)
    dt = time.time() - t0
    n_pairs = len(art.manifest["pairs"])
    print(f"prepared {args.arch} (scheme={args.scheme} "
          f"collective={art.manifest['policy']['collective']} "
          f"mesh={policy.mesh.shorthand()} "
          f"tp={args.tp}) -> {path}: {n_pairs} planned pair(s), "
          f"{len(art.manifest['leaf_shards'])} leaves, {dt:.1f}s")
    for site in art.manifest.get("collective_tuner", ()):
        # ':fused'-suffixed choices run the wire-epilogue kernel
        # (DESIGN.md §10); attn_vo sites are the V->O fold epilogues
        print(f"  tuned {site['path']} [{site.get('kind', 'pair')}]: "
              f"{site['chosen']} ({site['status']})")
    return path


def _load_artifact(args, *, manifest_only: bool = False):
    """Reconstruct (cfg, policy, artifact) from an artifact directory.

    The manifest is the single source of truth for the plan: the CLI's
    plan flags (--scheme/--backend/--collective/--arch) are ignored, and
    --tp defaults to the artifact's degree (an explicit --tp > 1 that
    disagrees fails ``validate``).  To serve a different plan, re-run
    ``prepare``.

    ``manifest_only`` (mesh mode): read just ``manifest.json`` and return
    a shell artifact with no rank pytrees — the engine loads this
    process's shards per-rank later, so the launcher never materializes
    ranks it doesn't own.
    """
    from repro.plan import DeploymentArtifact

    if manifest_only:
        art = DeploymentArtifact(
            manifest=DeploymentArtifact.load_manifest(args.artifact))
    else:
        art = DeploymentArtifact.load(args.artifact)
    man = art.manifest
    cfg = (get_smoke_config(man["arch_id"]) if man.get("smoke")
           else get_config(man["arch_id"]))
    cfg = cfg.with_quant(**man["quant"])
    policy = art.policy()
    # cache layout is runtime-only (excluded from validate): CLI kv flags
    # override the manifest's recorded layout on the POLICY, never on cfg
    # (mutating cfg would break the config-hash check against a plan that
    # is identical either way)
    if args.kv_page_size is not None or args.kv_bits is not None:
        from repro.cache import PageSpec

        policy = policy.with_(kv=PageSpec(page_size=args.kv_page_size,
                                          bits=args.kv_bits))
    tp = args.tp if args.tp > 1 else art.tp
    art.validate(cfg=cfg, policy=policy, tp=tp)
    return cfg, policy, art, tp


def _run_multiprocess(args, cfg, engine, tp):
    """Synthetic-batch generation for multi-controller launches.

    The Scheduler/HTTP front ends are single-controller (host-side
    per-request admission and slot bookkeeping); under
    ``jax.distributed`` every process must instead step the same
    lockstep program — one padded batch through ``engine.generate``.
    Sampling happens host-side on replicated logits with identical rngs,
    so every process emits identical tokens (the printed ``first=``
    prefix can be diffed across processes as a cheap coherence check).
    The batch must be divisible by the mesh's dp degree.
    """
    rng = np.random.default_rng(args.seed)
    b = args.max_batch
    plen = min(max(4, args.prompt_budget // 2), args.prompt_budget)
    tokens = rng.integers(0, cfg.vocab_size, size=(b, plen)).astype(np.int32)
    prompt_len = np.full((b,), plen, np.int32)
    t0 = time.time()
    toks = np.asarray(engine.generate(
        jax.random.PRNGKey(args.seed), {"tokens": tokens}, prompt_len,
        max_new_tokens=args.max_new))
    dt = time.time() - t0
    total = toks.shape[0] * toks.shape[1]
    print(f"process {jax.process_index()}/{jax.process_count()}: "
          f"generated {toks.shape[0]}x{toks.shape[1]} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s) first={toks[0, :8].tolist()}",
          flush=True)


def verify(argv=None):
    """``serve verify --artifact DIR``: static audit of a prepared
    artifact — the offline manifest lint (``repro.analysis``, MF rules)
    plus the collective dtype/shape contracts for exactly the specs the
    artifact's plan resolves, at the artifact's TP degree.  No model is
    built and no FLOPs are spent; exit 1 on error-severity findings."""
    ap = argparse.ArgumentParser(prog="repro.launch.serve verify")
    ap.add_argument("--artifact", required=True,
                    help="prepared DeploymentArtifact directory")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write findings as JSON")
    args = ap.parse_args(argv)

    from repro.analysis import contracts, manifest_lint
    from repro.analysis.findings import has_errors, to_json_text
    from repro.comm.spec import parse_collective
    from repro.plan import DeploymentArtifact

    manifest = DeploymentArtifact.load_manifest(args.artifact)
    findings = manifest_lint.run(artifact=args.artifact)
    coll = parse_collective(manifest["policy"]["collective"])
    tp = int(manifest["tp"])
    tps = tuple(t for t in (1, tp) if t <= jax.device_count())
    findings += contracts.lint_collectives(
        specs=[s.shorthand() for s in coll.specs()], tps=tps)
    for f in findings:
        print(f"  {f}")
    errs = sum(1 for f in findings if f.severity == "error")
    print(f"verify {args.artifact}: {len(findings)} finding(s), "
          f"{errs} error(s)")
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(to_json_text(findings))
    return 1 if has_errors(findings) else 0


def main(argv=None):
    import sys

    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "prepare":
        return prepare(argv[1:])
    if argv and argv[0] == "verify":
        return verify(argv[1:])

    ap = argparse.ArgumentParser()
    _plan_args(ap)
    _dist_args(ap)
    ap.add_argument("--artifact", default=None,
                    help="serve a prepared DeploymentArtifact directory "
                         "(skips quantize/plan at startup; the manifest "
                         "defines arch/scheme/backend/collective — plan "
                         "flags are ignored — and is validated against "
                         "the reconstructed config, policy, and mesh)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-budget", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--http", default=None, metavar="[HOST]:PORT",
                    help="serve over HTTP/SSE instead of the built-in "
                         "synthetic batch: POST /v1/generate streams "
                         "token events, GET /v1/health, GET /v1/stats "
                         "(':0' binds an ephemeral port)")
    ap.add_argument("--queue-capacity", type=int, default=64,
                    help="admission queue bound; a full wait line "
                         "answers 429 + Retry-After (HTTP mode)")
    args = ap.parse_args(argv)

    # multi-controller join MUST precede the first device/backend touch
    # (artifact loading already puts leaves on device)
    mesh_lib.init_distributed(args.coordinator, args.num_processes,
                              args.process_id)

    if args.mesh is not None and args.tp <= 1:
        args.tp = args.mesh.tp

    if args.artifact:
        cfg, policy, artifact, tp = _load_artifact(
            args, manifest_only=args.mesh is not None)
        if args.mesh is not None:
            # engine loads this process's shards per-rank from the path
            artifact = args.artifact
    else:
        cfg = _build_cfg(args)
        policy = ExecutionPolicy.from_config(cfg)
        artifact, tp = None, args.tp

    if isinstance(policy.collective, CollectivePlan):
        # name where the per-layer plan came from, and what it resolves to
        src = ("artifact manifest" if args.artifact
               else "--collective flag")
        plan = policy.collective
        print(f"per-layer collective plan ({src}): "
              + ", ".join(f"{pat} -> {spec.shorthand()}"
                          for pat, spec in plan.entries)
              + f", default -> {plan.default.shorthand()}")

    if args.mesh is not None:
        if args.mesh.tp != tp:
            raise SystemExit(
                f"--mesh {args.mesh.shorthand()} (tp={args.mesh.tp}) "
                f"disagrees with the plan's TP degree {tp}")
        # downstream BENCH_* snapshots record the serving grid
        os.environ["REPRO_MESH"] = args.mesh.shorthand()
        policy = policy.with_(mesh=args.mesh)
        mesh = args.mesh.build_mesh()
        ctx = ParallelContext(mesh=mesh, batch_axes=("data",),
                              policy=policy)
    elif tp > 1:
        mesh = mesh_lib.make_host_mesh(model=tp)
        ctx = ParallelContext(mesh=mesh, batch_axes=("data",),
                              policy=policy)
    else:
        ctx = REPLICATED

    max_seq = args.prompt_budget + args.max_new + 1
    engine = make_engine(cfg, jax.random.PRNGKey(args.seed), ctx=ctx,
                         max_seq=max_seq, policy=policy, artifact=artifact,
                         per_rank=True if (args.mesh is not None
                                           and args.artifact) else None)

    if args.mesh is not None:
        st = engine.load_stats
        resident = (f"resident_artifact_bytes="
                    f"{st.file_bytes_loaded}/{st.file_bytes_total} "
                    f"ranks={list(st.ranks)}" if st is not None
                    else "resident_artifact_bytes=n/a (in-memory plan)")
        print(f"mesh={args.mesh.shorthand()} "
              f"process={jax.process_index()}/{jax.process_count()} "
              f"{resident}", flush=True)

    if jax.process_count() > 1:
        return _run_multiprocess(args, cfg, engine, tp)

    if args.http is not None:
        from repro.serving import ServingServer

        host, _, port = args.http.rpartition(":")
        srv = ServingServer(
            engine, host=host or "127.0.0.1", port=int(port or 0),
            max_batch=args.max_batch, prompt_budget=args.prompt_budget,
            scfg=SamplingConfig(temperature=args.temperature, top_k=40),
            seed=args.seed, queue_capacity=args.queue_capacity)
        src = (f"artifact={args.artifact}" if args.artifact
               else "in-memory plan")
        print(f"serving {cfg.arch_id} on http://{srv.address[0]}:"
              f"{srv.port} [scheme={policy.scheme} "
              f"backend={policy.backend} "
              f"collective={policy.collective.shorthand()} "
              f"kv={policy.kv.shorthand()} tp={tp} "
              f"max_batch={args.max_batch} "
              f"queue={args.queue_capacity} {src}]", flush=True)
        srv.serve_forever()
        return

    sched = Scheduler(engine, max_batch=args.max_batch,
                      prompt_budget=args.prompt_budget,
                      scfg=SamplingConfig(temperature=args.temperature,
                                          top_k=40),
                      seed=args.seed)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, args.prompt_budget))
        sched.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=args.max_new))
    done = sched.run()
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in done.values())
    for rid, r in sorted(done.items()):
        print(f"req {rid}: prompt {len(r.prompt):3d} -> {r.output[:8]}...")
    src = f"artifact={args.artifact}" if args.artifact else "in-memory plan"
    print(f"\n{len(done)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s) [scheme={policy.scheme} "
          f"backend={policy.backend} "
          f"collective={policy.collective.shorthand()} {src}]")


if __name__ == "__main__":
    main()
