"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices before any jax
import; smoke tests see 1 device).
"""

from __future__ import annotations

import jax

SINGLE_POD = (16, 16)            # 256 chips (one v5e pod slice)
MULTI_POD = (2, 16, 16)          # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run "
            "under launch/dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    if n % model:
        raise ValueError(f"{n} devices not divisible by model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes_for(mesh: jax.sharding.Mesh, global_batch: int) -> tuple:
    """Batch-sharding axes usable for this mesh and batch size.

    Decode at batch=1 (long_500k) cannot shard its batch dim — returns ()
    so the batch is replicated and only the model axis does real work.
    """
    axes = [a for a in mesh.axis_names if a in ("pod", "data")]
    out = []
    size = 1
    for a in axes:
        s = dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        if global_batch % (size * s) == 0:
            out.append(a)
            size *= s
    return tuple(out)
