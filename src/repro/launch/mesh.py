"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices before any jax
import; smoke tests see 1 device).
"""

from __future__ import annotations

import jax

SINGLE_POD = (16, 16)            # 256 chips (one v5e pod slice)
MULTI_POD = (2, 16, 16)          # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run "
            "under launch/dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def init_distributed(coordinator: str, num_processes: int,
                     process_id: int) -> None:
    """Join a multi-controller JAX job (no-op for a single process).

    MUST run before anything touches devices: the CPU collectives
    implementation is a backend-creation option, so the gloo flag has to
    be set before the backend initializes — which is also why this module
    keeps everything behind functions.  TPU fleets ignore the flag (ICI
    collectives are native); on CPU it is what lets two loopback
    processes run real ppermute/psum rings over sockets.
    """
    if num_processes <= 1:
        return
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - flag renamed/absent on new jax
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)


def make_plan_mesh(plan) -> jax.sharding.Mesh:
    """Materialize a ``dist.MeshPlan`` over the global device grid."""
    return plan.build_mesh()


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    if n % model:
        raise ValueError(f"{n} devices not divisible by model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes_for(mesh: jax.sharding.Mesh, global_batch: int) -> tuple:
    """Batch-sharding axes usable for this mesh and batch size.

    Decode at batch=1 (long_500k) cannot shard its batch dim — returns ()
    so the batch is replicated and only the model axis does real work.
    """
    axes = [a for a in mesh.axis_names if a in ("pod", "data")]
    out = []
    size = 1
    for a in axes:
        s = dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        if global_batch % (size * s) == 0:
            out.append(a)
            size *= s
    return tuple(out)
