"""Roofline analysis from compiled dry-run artifacts.

Three terms, per (arch × shape × mesh), all in seconds (TPU v5e targets):

* compute    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
* memory     = HLO_bytes / (chips × 819 GB/s HBM)
* collective = collective_bytes / (chips × 50 GB/s ICI link)

``cost_analysis()`` reports whole-program FLOPs/bytes (already summed over
the SPMD program = per-device value × chips).  collective_bytes is parsed
from the compiled HLO text: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction we model the
per-device ICI traffic of a ring/bidirectional implementation from the
instruction's result shape and replica-group size, then multiply by chips
to get the global number the formula above divides back down.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

# --- hardware constants (TPU v5e) -----------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %all-gather.3 = bf16[4,1792]{1,0} all-gather(%x), ...
# ('-done' lines never match; an async '-start' is counted once here)
_INSTR_RE = re.compile(
    r"=\s*([a-z0-9_]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
# tuple-result form that *synchronous* multi-operand collectives lower to:
#   %all-to-all.4 = (s8[8,4096]{...}, /*index=1*/ f16[8,32]{...}) all-to-all(...)
# the result bytes are the sum of every tuple entry.  Deliberately does
# NOT accept '-start' here: async tuple results alias their operands
# ((in, out) pairs), so summing the entries would double-count — those
# keep the old behavior (simple form counted, tuple form skipped).
_TUPLE_INSTR_RE = re.compile(
    r"=\s*\((.*?)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([A-Za-z0-9_.\-]+)\s*=")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _ring_bytes(kind: str, r: float, g: int) -> float:
    """Ring cost model (g = replica-group size, R = result bytes/device):
      all-gather       : R × (g-1)/g      (result is the gathered tensor)
      all-reduce       : R × 2(g-1)/g     (reduce-scatter + all-gather)
      reduce-scatter   : R × (g-1)        (input = R×g, moves (g-1)/g of it)
      all-to-all       : R × (g-1)/g
      collective-permute: R               (point-to-point)
    """
    if kind == "all-gather":
        return r * (g - 1) / g
    if kind == "all-reduce":
        return r * 2 * (g - 1) / g
    if kind == "reduce-scatter":
        return r * (g - 1)
    if kind == "all-to-all":
        return r * (g - 1) / g
    return r  # collective-permute


def iter_collectives(hlo_text: str, *, chips: int):
    """Structured per-instruction view of a module's collectives.

    Yields one dict per matched collective instruction — the substrate
    ``repro.analysis.hlo_lint``'s rule engine and the byte accounting
    below share: ``{"kind", "name", "line", "dtype", "result_bytes",
    "group", "bytes"}`` where ``bytes`` applies the ring cost model and
    ``group`` is the replica-group size (``chips`` when the instruction
    names none).  ``dtype`` is None for tuple-result forms (mixed
    payload/scale dtypes).
    """
    for lineno, line in enumerate(hlo_text.splitlines(), 1):
        m = _INSTR_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            r = _shape_bytes(dtype, dims)
        else:
            m = _TUPLE_INSTR_RE.search(line)
            if not m:
                continue
            shapes, kind = m.groups()
            dtype = None
            r = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(shapes))
        g = chips
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        g = max(g, 1)
        mn = _NAME_RE.match(line)
        yield {
            "kind": kind,
            "name": mn.group(1) if mn else "",
            "line": lineno,
            "dtype": dtype,
            "result_bytes": r,
            "group": g,
            "bytes": _ring_bytes(kind, r, g),
        }


def parse_collective_bytes(hlo_text: str, *, chips: int) -> dict:
    """Per-device ICI bytes by collective kind, modeled from compiled HLO
    (ring cost model — see ``_ring_bytes``)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for instr in iter_collectives(hlo_text, chips=chips):
        out[instr["kind"]] += instr["bytes"]
        counts[instr["kind"]] += 1
    out["total_per_device"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# async-window verification (dist/overlap.py, DESIGN.md §11)
# ---------------------------------------------------------------------------

# an instruction definition: "  %name = <result> <opcode>(operands...)" —
# opcode is the first bare token after the result type(s)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([A-Za-z0-9_.\-]+)\s*=\s*"
    r"(?:\([^)]*\)|[a-z0-9_]+\[[\d,]*\]\S*)\s+([a-z0-9\-]+)")
_OPERAND_RE = re.compile(r"%([A-Za-z0-9_.\-]+)")
# a computation header: "%comp_name (param: ...) -> result {" / "ENTRY %..."
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([A-Za-z0-9_.\-]+)\s*\(.*\{\s*$")
_GEMM_OPS = ("dot", "custom-call")   # plain dots (CPU/interpret mode) or
                                     # Pallas custom-calls (TPU)


def parse_overlap_windows(hlo_text: str,
                          kinds=("collective-permute",)) -> dict:
    """Async-window analysis of a *scheduled* compiled HLO module.

    For every collective of ``kinds`` (synchronous form or async
    ``-start``), the window is the span of scheduled instructions
    strictly between the collective and the first instruction that
    consumes its result (for async pairs that consumer is the ``-done``).
    A window containing a GEMM means the scheduler placed compute inside
    the collective's in-flight span — the overlap ``dist/overlap.py``
    pipelines for, on both encodings: backends with async collectives
    emit explicit start/done tuples, while CPU XLA keeps the
    instructions synchronous but the printed module *is* the schedule
    (``is_scheduled=true``), so instruction order between issue and
    first use is exactly the overlap window.

    A GEMM is a ``dot`` or ``custom-call`` instruction, directly or
    transitively inside a called computation (fusions, Pallas interpret
    grid loops, and scanned layers wrap the dot in ``fusion`` / ``call``
    / ``while`` ops whose bodies are separate computations).  Windows are
    scanned per computation body — ``lax.scan`` rings live in while-loop
    bodies, not ENTRY.

    Returns ``{"collectives": N, "spanning": M, "windows": [...]}`` where
    each window records the instruction name, window length, and how
    many GEMM-containing instructions it spans.
    """
    # pass 1: per computation, the instruction list and referenced comps
    comps: dict = {}
    cur_name, body = None, []
    for line in hlo_text.splitlines():
        mdef = _DEF_RE.match(line)
        if mdef:
            name, opcode = mdef.groups()
            rhs = line.split("=", 1)[1]
            operands = set(_OPERAND_RE.findall(rhs)) - {name}
            body.append((name, opcode, operands))
            continue
        mcomp = _COMP_RE.match(line)
        if mcomp:
            cur_name, body = mcomp.group(1), []
            comps[cur_name] = body
        elif line.strip().startswith("}") and cur_name is not None:
            cur_name = None

    # pass 2: which computations (transitively) contain a GEMM
    has_gemm: dict = {}

    def _contains_gemm(comp, seen=()):
        if comp in has_gemm:
            return has_gemm[comp]
        if comp in seen:
            return False
        out = False
        for _, opcode, operands in comps.get(comp, ()):
            if opcode in _GEMM_OPS:
                out = True
                break
            if any(_contains_gemm(ref, seen + (comp,))
                   for ref in operands if ref in comps):
                out = True
                break
        has_gemm[comp] = out
        return out

    def _is_gemm(opcode, operands):
        return opcode in _GEMM_OPS or any(
            _contains_gemm(ref) for ref in operands if ref in comps)

    # pass 3: windows
    windows = []
    for comp, instrs in comps.items():
        for i, (name, opcode, _) in enumerate(instrs):
            if not any(opcode == k or opcode == k + "-start"
                       for k in kinds):
                continue
            gemms, wlen = 0, 0
            for _, opcode2, operands2 in instrs[i + 1:]:
                if name in operands2:
                    break
                wlen += 1
                if _is_gemm(opcode2, operands2):
                    gemms += 1
            windows.append({"computation": comp, "name": name,
                            "opcode": opcode, "window_len": wlen,
                            "gemms": gemms})
    return {
        "collectives": len(windows),
        "spanning": sum(1 for w in windows if w["gemms"]),
        "windows": windows,
    }


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float       # global (per-device × chips)
    model_flops: float            # 6·N·D (train) or 2·N_active·D (serve)
    per_device_hbm: Optional[float] = None   # memory_analysis total
    collective_detail: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "per_device_hbm": self.per_device_hbm,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "collective_detail": self.collective_detail,
        }


def from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                  chips: int, model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo, chips=chips)
    mem = compiled.memory_analysis()
    per_dev = None
    if mem is not None:
        per_dev = float(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes,
        collective_bytes=coll["total_per_device"] * chips,
        model_flops=model_flops, per_device_hbm=per_dev,
        collective_detail=coll)


def fmt_seconds(s: float) -> str:
    if s <= 0:
        return "0"
    if s < 1e-3:
        return f"{s * 1e6:.1f}us"
    if s < 1:
        return f"{s * 1e3:.2f}ms"
    return f"{s:.2f}s"


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"
