"""Training entrypoint (single-host scale; the same code path the dry-run
lowers at production scale).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch import mesh as mesh_lib
from repro.models.common import ParallelContext, REPLICATED
from repro.models.registry import build_model
from repro.train import checkpoint, data as data_lib, optimizer as opt
from repro.train import trainstep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis size over available devices")
    ap.add_argument("--data", default=None, help="token file (uint16)")
    ap.add_argument("--ckpt", default=None, help="checkpoint path prefix")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).with_quant(mode="none")
    model = build_model(cfg)

    if args.tp > 1 or len(jax.devices()) > 1:
        mesh = mesh_lib.make_host_mesh(model=args.tp)
        ctx = ParallelContext(mesh=mesh, batch_axes=("data",))
    else:
        mesh, ctx = None, REPLICATED

    ocfg = opt.AdamWConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(args.steps // 20, 1))
    state = trainstep.init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(trainstep.make_train_step(model, ctx, ocfg),
                      donate_argnums=0)

    dcfg = data_lib.DataConfig(seq_len=args.seq, global_batch=args.batch,
                               vocab_size=cfg.vocab_size, path=args.data)
    batches = data_lib.batches(dcfg)

    t0 = time.time()
    for i in range(args.steps):
        batch = next(batches)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        state, metrics = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {i:5d} loss {loss:7.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")

    if args.ckpt:
        path = checkpoint.save(args.ckpt, state["params"],
                               step=int(metrics["step"]))
        print("saved", path)


if __name__ == "__main__":
    main()
