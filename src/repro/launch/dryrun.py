import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) pair.

No real allocation: params/batch/cache are ShapeDtypeStructs
(``jax.eval_shape`` over the real init functions) and the program is only
``.lower().compile()``'d.  Proves the sharding config is coherent at
production scale and yields the cost/memory/collective numbers for
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
"""

import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.policy import ExecutionPolicy
from repro.launch import mesh as mesh_lib, roofline
from repro.models.common import ParallelContext
from repro.models.registry import Model, build_model
from repro.train import optimizer as opt, trainstep


# ---------------------------------------------------------------------------
# struct helpers
# ---------------------------------------------------------------------------

def _cast_float_structs(tree, dtype=jnp.bfloat16):
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype)
        return x
    return jax.tree.map(cast, tree)


def _shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def param_structs(model: Model, *, bf16: bool) -> dict:
    structs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return _cast_float_structs(structs) if bf16 else structs


# ---------------------------------------------------------------------------
# program builders (one per input-shape kind)
# ---------------------------------------------------------------------------

def _tp_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def lower_train(model: Model, mesh, shape, scheme: str,
                chunk_scan: bool = True):
    """train_4k: dense model (quantization is an inference artifact),
    full AdamW train step with donated state and remat."""
    cfg = model.cfg.with_quant(mode="none").with_(attn_tp_pad=_tp_size(mesh))
    model = build_model(cfg)
    baxes = mesh_lib.batch_axes_for(mesh, shape.global_batch)
    ctx = ParallelContext(mesh=mesh, batch_axes=baxes, remat=True,
                          chunk_scan=chunk_scan)

    pstructs = param_structs(model, bf16=False)
    state_structs = {"params": pstructs,
                     "opt": jax.eval_shape(opt.init_state, pstructs)}
    batch_structs = model.batch_shape_structs(
        shape.global_batch, shape.seq_len, with_labels=True)

    pspecs = model.param_specs(pstructs, ctx)
    state_specs = {"params": pspecs, "opt": opt.state_specs(pspecs)}
    bspecs = model.batch_specs(ctx, with_labels=True)

    ocfg = opt.AdamWConfig()
    step = trainstep.make_train_step(model, ctx, ocfg)

    jitted = jax.jit(
        step,
        in_shardings=(_shardings(mesh, state_specs),
                      _shardings(mesh, bspecs)),
        donate_argnums=0)
    return jitted.lower(state_structs, batch_structs)


def lower_prefill(model: Model, mesh, shape, scheme: str,
                  chunk_scan: bool = True, ctx_overrides=None):
    """prefill_32k: quantized deployment forward -> logits."""
    cfg = model.cfg.with_quant(mode="mlp", scheme=scheme).with_(
        attn_tp_pad=_tp_size(mesh))
    model = build_model(cfg)
    baxes = mesh_lib.batch_axes_for(mesh, shape.global_batch)
    # backend pinned to jnp: cost_analysis must see the dequant+GEMM FLOPs,
    # which the XLA path exposes and an opaque pallas_call would hide
    policy = ExecutionPolicy.from_config(cfg).with_(backend="jnp")
    ctx = ParallelContext(mesh=mesh, batch_axes=baxes, remat=True,
                          chunk_scan=chunk_scan,
                          **{"policy": policy, **(ctx_overrides or {})})

    pstructs = param_structs(model, bf16=True)
    batch_structs = model.batch_shape_structs(shape.global_batch,
                                              shape.seq_len)
    pspecs = model.param_specs(pstructs, ctx)
    bspecs = model.batch_specs(ctx)

    window = cfg.attention_window if shape.seq_len > 32_768 else None

    def prefill(params, batch):
        return model.forward(params, batch, ctx, window=window)

    jitted = jax.jit(
        prefill,
        in_shardings=(_shardings(mesh, pspecs), _shardings(mesh, bspecs)))
    return jitted.lower(pstructs, batch_structs)


def lower_decode(model: Model, mesh, shape, scheme: str,
                 chunk_scan: bool = True):
    """decode_32k / long_500k: one-token serve_step with KV/state cache."""
    cfg = model.cfg.with_quant(mode="mlp", scheme=scheme).with_(
        attn_tp_pad=_tp_size(mesh))
    model = build_model(cfg)
    window = model.decode_window(shape.seq_len)   # raises for whisper@500k
    baxes = mesh_lib.batch_axes_for(mesh, shape.global_batch)
    policy = ExecutionPolicy.from_config(cfg).with_(backend="jnp")
    ctx = ParallelContext(mesh=mesh, batch_axes=baxes,
                          chunk_scan=chunk_scan, policy=policy)

    pstructs = param_structs(model, bf16=True)
    cache_structs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 window=window))
    tok_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

    pspecs = model.param_specs(pstructs, ctx)
    cspecs = model.cache_specs(ctx)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, ctx,
                                 window=window)

    jitted = jax.jit(
        serve_step,
        in_shardings=(_shardings(mesh, pspecs), _shardings(mesh, cspecs),
                      NamedSharding(mesh, P(ctx.batch_spec)),
                      NamedSharding(mesh, P())),
        donate_argnums=1)
    return jitted.lower(pstructs, cache_structs, tok_struct, pos_struct)


_LOWER = {"train": lower_train, "prefill": lower_prefill,
          "decode": lower_decode}


# ---------------------------------------------------------------------------
# cost extraction.  Two XLA facts (verified empirically):
#   * cost_analysis() numbers are PER-DEVICE on an SPMD module,
#   * a lax.scan (while-loop) body is counted ONCE regardless of length —
#     so a length-1 scan is counted exactly, and a length-0 scan contributes
#     nothing.  We therefore probe f(0) and f(one unit of each scanned
#     stack) and assemble  total = f(0) + Σ_stacks n_units × Δ_unit .
# ---------------------------------------------------------------------------

def _raw_cost(compiled, chips: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = roofline.parse_collective_bytes(compiled.as_text(), chips=chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total_per_device"],
        "counts": coll["counts"],
    }


def _cost_lin(c1: dict, c2: dict, a: float, b: float) -> dict:
    """a*c1 + b*c2 elementwise on the numeric fields."""
    out = {k: a * c1[k] + b * c2[k] for k in ("flops", "bytes", "coll")}
    out["counts"] = c2.get("counts", c1.get("counts"))
    return out


def probe_plan(cfg):
    """Probe configs + combiner for the f(0)/f(unit) decomposition.

    Every probe keeps all dimensions at full size; only scanned layer
    counts shrink to 0 or 1 unit so cost_analysis counts each scan body
    exactly (once) or not at all.
    """
    fam = cfg.family
    if fam == "vlm":
        ce = cfg.cross_attn_every
        ns = cfg.num_layers // ce
        n_self = cfg.num_layers - ns
        # f0: no layers.  fx: one superblock of (1 cross, 0 self) via
        # cross_attn_every=1.  fs: one superblock of (1 cross, 1 self).
        probes = {
            "f0": cfg.with_(num_layers=0),
            "fx": cfg.with_(num_layers=1, cross_attn_every=1),
            "fs": cfg.with_(num_layers=2, cross_attn_every=2),
        }

        def combine(c):
            d_cross = _cost_lin(c["fx"], c["f0"], 1.0, -1.0)
            d_self = _cost_lin(c["fs"], c["fx"], 1.0, -1.0)
            total = _cost_lin(c["f0"], d_cross, 1.0, ns)
            return _cost_lin(total, d_self, 1.0, n_self)
    elif fam == "audio":
        probes = {
            "f0": cfg.with_(num_layers=0, encoder_layers=0),
            "fe": cfg.with_(num_layers=0, encoder_layers=1),
            "fd": cfg.with_(num_layers=1, encoder_layers=0),
        }
        n_enc, n_dec = cfg.encoder_layers, cfg.num_layers

        def combine(c):
            d_enc = _cost_lin(c["fe"], c["f0"], 1.0, -1.0)
            d_dec = _cost_lin(c["fd"], c["f0"], 1.0, -1.0)
            total = _cost_lin(c["f0"], d_enc, 1.0, n_enc)
            return _cost_lin(total, d_dec, 1.0, n_dec)
    elif fam == "hybrid":
        ns, nx = cfg.num_layers // 3, cfg.num_layers % 3
        # num_layers=3 -> 1 superblock, 0 extra; num_layers=1 -> 0 super,
        # 1 extra recurrent layer (length-1 scans, counted exactly).
        probes = {
            "f0": cfg.with_(num_layers=0),
            "fs": cfg.with_(num_layers=3),
            "fr": cfg.with_(num_layers=1),
        }

        def combine(c):
            d_super = _cost_lin(c["fs"], c["f0"], 1.0, -1.0)
            d_rec = _cost_lin(c["fr"], c["f0"], 1.0, -1.0)
            total = _cost_lin(c["f0"], d_super, 1.0, ns)
            return _cost_lin(total, d_rec, 1.0, nx)
    else:  # dense / moe / ssm: one plain layer scan
        probes = {"f0": cfg.with_(num_layers=0),
                  "f1": cfg.with_(num_layers=1)}
        n = cfg.num_layers

        def combine(c):
            d = _cost_lin(c["f1"], c["f0"], 1.0, -1.0)
            return _cost_lin(c["f0"], d, 1.0, n)
    return probes, combine


def analytic_extra_flops(cfg, shape) -> float:
    """Within-layer sequence scans that cost_analysis can't see.

    RWKV-6's WKV recurrence runs a lax.scan over the sequence inside each
    layer: ~6 flops per (head, dk, dv) cell per token.  Global count.
    """
    if cfg.family != "ssm" or shape.kind == "decode":
        return 0.0
    h = cfg.d_model // cfg.rwkv_head_dim
    cell = h * cfg.rwkv_head_dim * cfg.rwkv_head_dim * 6
    tokens = shape.global_batch * shape.seq_len
    return float(cfg.num_layers) * cell * tokens


def model_flops(cfg, shape) -> float:
    """Useful FLOPs: 6·N·D train, 2·N_active·D inference (D = tokens).

    MoE uses N_active in both cases (6·N_active·D per the assignment) —
    the compiled program only runs top-k experts.  NOTE: 6ND/2ND counts
    parameter FLOPs only; the S² attention term is excluded by the
    metric's definition, so long-context attention-heavy configs
    legitimately show useful_flops_frac << 1 (EXPERIMENTS.md §Roofline).
    """
    n = cfg.active_param_count() if cfg.num_experts else (
        cfg.param_count() if shape.kind == "train"
        else cfg.active_param_count())
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * tokens


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            scheme: str = "tp-aware",
            verbose: bool = True) -> Optional[dict]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    model = build_model(cfg)

    t0 = time.time()
    try:
        lowered = _LOWER[shape.kind](model, mesh, shape, scheme)
    except ValueError as e:
        if "skipped" in str(e) or "sliding-window" in str(e):
            print(f"SKIP  {arch} × {shape_name}: {e}")
            return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "skipped": str(e)}
        raise
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    # layer-probe extrapolation (scan bodies are counted once; see above)
    probes, combine = probe_plan(cfg)
    pcosts = {}
    for label, pcfg in probes.items():
        plow = _LOWER[shape.kind](build_model(pcfg), mesh, shape, scheme,
                                  chunk_scan=False)
        pcosts[label] = _raw_cost(plow.compile(), chips)
    cost = combine(pcosts)

    mem = compiled.memory_analysis()
    per_dev = float(getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0))
    extra = analytic_extra_flops(cfg, shape)
    rl = roofline.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=cost["flops"] * chips + extra,
        hlo_bytes=cost["bytes"] * chips,
        collective_bytes=cost["coll"] * chips,
        model_flops=model_flops(cfg, shape),
        per_device_hbm=per_dev,
        collective_detail={"counts": cost["counts"],
                           "analytic_extra_flops": extra})
    rec = rl.to_json()
    rec.update(scheme=scheme, t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1))

    if verbose:
        print(f"OK    {arch} × {shape_name} × {mesh_name} [{scheme}] "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"      per-device HBM: args={roofline.fmt_bytes(getattr(mem, 'argument_size_in_bytes', 0))} "
              f"temp={roofline.fmt_bytes(getattr(mem, 'temp_size_in_bytes', 0))} "
              f"out={roofline.fmt_bytes(getattr(mem, 'output_size_in_bytes', 0))}")
        print(f"      flops={rl.hlo_flops:.3e} bytes={rl.hlo_bytes:.3e} "
              f"coll={roofline.fmt_bytes(rl.collective_bytes)} "
              f"counts={rl.collective_detail['counts']}")
        print(f"      t_comp={roofline.fmt_seconds(rl.t_compute)} "
              f"t_mem={roofline.fmt_seconds(rl.t_memory)} "
              f"t_coll={roofline.fmt_seconds(rl.t_collective)} "
              f"bottleneck={rl.bottleneck} "
              f"useful={rl.useful_flops_frac:.2f}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--scheme", default="tp-aware")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_one(arch, shape, multi_pod=mp,
                                  scheme=args.scheme)
                except Exception as e:
                    print(f"FAIL  {arch} × {shape} multi_pod={mp}: {e}")
                    traceback.print_exc()
                    failures.append((arch, shape, mp))
                    continue
                if rec:
                    records.append(rec)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(rec) + "\n")
    print(f"\n{len(records)} lowered+compiled, {len(failures)} failures")
    if failures:
        for f_ in failures:
            print("  FAILED:", f_)
        sys.exit(1)


if __name__ == "__main__":
    main()
