"""Paged KV/state cache subsystem (DESIGN.md §9).

``PageSpec`` (the policy knob) -> ``PagedCacheManager`` (host page
tables, prefix sharing, reservations) -> ``paged`` (device pool,
gather/scatter, quantized page codec) on top of ``PageAllocator`` /
``PrefixStore``.
"""

from repro.cache.allocator import OutOfPages, PageAllocator
from repro.cache.manager import PagedCacheManager
from repro.cache.prefix import PrefixStore, chain_keys
from repro.cache.spec import PageSpec

__all__ = [
    "OutOfPages", "PageAllocator", "PagedCacheManager", "PrefixStore",
    "chain_keys", "PageSpec",
]
