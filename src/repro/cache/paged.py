"""Paged KV pool: device-side layout, gather/scatter, quantized pages.

The pool replaces the dense per-slot cache rows ``(..., B, cap, KV, D)``
with a shared page pool ``(..., N_pages, page_size, KV, D)`` plus a
host-managed per-slot page table ``(B, Pmax)`` of int32 page indices
(``cache/manager.py``).  Decode gathers a slot's logical cache by page
index and scatters the new token into ``(table[b, pos // ps], pos % ps)``
— memory scales with *live* tokens, not worst-case sequence.

Quantized pages (``PageSpec.bits``) store uint8 / nibble-packed-uint32
codes with an asymmetric (scale, zero) pair per (token, head) row over
head_dim — the same min/max scheme ``core/quantization`` uses per group,
and int4 packing goes through its ``pack_int4``/``unpack_int4``.  The
variant is carried entirely by the pool leaves' dtypes (uint8 -> int8,
uint32 -> int4, float -> raw), so one jitted decode signature serves all
three: jit specializes on the pytree structure + dtypes, no static
flags.

Error model: dequantized values differ from the stored activations by at
most ``(max - min) / (2 * qmax)`` per (token, head) row (round-to-
nearest on a qmax-level asymmetric grid); the fp pool is bit-exact with
the dense cache.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.quantization import pack_int4, unpack_int4

INT8_QMAX = 255
INT4_QMAX = 15


def pool_bits(pool: dict) -> Optional[int]:
    """Page payload width, recovered from the pool's own dtypes."""
    dt = pool["k"].dtype
    if dt == jnp.uint8:
        return 8
    if dt == jnp.uint32:
        return 4
    return None


def init_pool(lead: tuple, n_pages: int, page_size: int, kv_heads: int,
              head_dim: int, *, dtype=jnp.bfloat16,
              bits: Optional[int] = None) -> dict:
    """Zeroed page pool with leading (layer-stack) dims ``lead``."""
    body = (n_pages, page_size, kv_heads)
    if bits is None:
        shape = lead + body + (head_dim,)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if bits == 8:
        codes = lead + body + (head_dim,)
        code_dtype = jnp.uint8
    elif bits == 4:
        if head_dim % 8:
            raise ValueError(
                f"int4 pages need head_dim % 8 == 0, got {head_dim}")
        codes = lead + body + (head_dim // 8,)
        code_dtype = jnp.uint32
    else:
        raise ValueError(f"kv bits must be None, 8 or 4, got {bits}")
    meta = lead + body
    pool = {}
    for name in ("k", "v"):
        pool[name] = jnp.zeros(codes, code_dtype)
        pool[f"{name}_scale"] = jnp.zeros(meta, jnp.float32)
        pool[f"{name}_zero"] = jnp.zeros(meta, jnp.float32)
    return pool


def pool_page_bytes(pool: dict, n_pages: int) -> tuple[int, int]:
    """(actual, fp-equivalent) bytes per page, over all layer dims.

    ``fp-equivalent`` prices the same logical (token, head, head_dim)
    values at the dense cache's bf16 width — the baseline the stats
    endpoint reports quantized savings against.
    """
    actual = sum(int(leaf.nbytes) for leaf in pool.values())
    fp = 0
    for name in ("k", "v"):
        leaf = pool[name]
        values = leaf.size * (8 if leaf.dtype == jnp.uint32 else 1)
        fp += values * 2
    return actual // n_pages, fp // n_pages


# ---------------------------------------------------------------------------
# quantized page codec — per (token, head) asymmetric min/max over head_dim
# ---------------------------------------------------------------------------

def _quantize_rows(x: jnp.ndarray, qmax: int):
    """x: (..., D) -> (codes int32 in [0, qmax], scale, zero) per row."""
    x32 = x.astype(jnp.float32)
    wmin = jnp.min(x32, axis=-1)
    wmax = jnp.max(x32, axis=-1)
    scale = (wmax - wmin) / qmax
    # all-equal rows (e.g. zero-init) quantize through scale 1 exactly
    scale = jnp.where(scale > 0, scale, 1.0)
    zero = jnp.clip(jnp.round(-wmin / scale), 0, qmax)
    codes = jnp.clip(jnp.round(x32 / scale[..., None] + zero[..., None]),
                     0, qmax).astype(jnp.int32)
    return codes, scale, zero


def _dequantize_rows(codes: jnp.ndarray, scale: jnp.ndarray,
                     zero: jnp.ndarray) -> jnp.ndarray:
    return (codes.astype(jnp.float32) - zero[..., None]) * scale[..., None]


def _pack_last(codes: jnp.ndarray) -> jnp.ndarray:
    """Nibble-pack int codes along the last axis via ``pack_int4``
    (which packs along the first): (..., D) -> (..., D // 8) uint32."""
    lead = codes.shape[:-1]
    d = codes.shape[-1]
    flat = codes.reshape(-1, d).T                       # (D, X)
    packed = pack_int4(flat)                            # (D // 8, X)
    return packed.T.reshape(*lead, d // 8)


def _unpack_last(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., D // 8) uint32 -> (..., D) int32 codes."""
    lead = packed.shape[:-1]
    d8 = packed.shape[-1]
    flat = packed.reshape(-1, d8).T                     # (D // 8, X)
    codes = unpack_int4(flat)                           # (D, X)
    return codes.T.reshape(*lead, d8 * 8)


# ---------------------------------------------------------------------------
# gather / scatter
# ---------------------------------------------------------------------------

def gather(pool: dict, pages: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize each slot's logical cache from its page list.

    pool: one layer's pool (no layer dims) — {"k","v": (N, ps, KV, D)}
    (+ scale/zero for quantized); pages: (B, Pmax) int32.  Returns
    (k, v): (B, Pmax * ps, KV, D), f32 for quantized pools, pool dtype
    for raw.  Unallocated table entries point at the scratch page
    (``manager.py``); the caller's position mask hides those columns
    (score -1e30 -> exp == 0.0 exactly), so garbage pages never
    contribute.
    """
    bits = pool_bits(pool)
    b, pmax = pages.shape
    ps = pool["k"].shape[1]

    def one(name):
        tile = pool[name][pages]                 # (B, Pmax, ps, KV, [D])
        if bits is None:
            out = tile
        else:
            codes = _unpack_last(tile) if bits == 4 else tile
            out = _dequantize_rows(codes, pool[f"{name}_scale"][pages],
                                   pool[f"{name}_zero"][pages])
        kv, d = out.shape[-2], out.shape[-1]
        return out.reshape(b, pmax * ps, kv, d)

    return one("k"), one("v")


def scatter_token(pool: dict, k: jnp.ndarray, v: jnp.ndarray,
                  pages: jnp.ndarray, pos: jnp.ndarray) -> dict:
    """Write one token per slot at its page-table position.

    k/v: (B, KV, D); pages: (B, Pmax); pos: (B,) per-slot positions.
    Slots sharing a page write idempotently (identical prefixes produce
    identical K/V, see ``cache/prefix.py``), so duplicate (page, offset)
    targets are safe regardless of scatter order.
    """
    bits = pool_bits(pool)
    ps = pool["k"].shape[1]
    b = k.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    pids = jnp.take_along_axis(pages, (pos // ps)[:, None], axis=1)[:, 0]
    offs = pos % ps

    new = dict(pool)
    for name, val in (("k", k), ("v", v)):
        if bits is None:
            new[name] = pool[name].at[pids, offs].set(
                val.astype(pool[name].dtype))
            continue
        qmax = INT4_QMAX if bits == 4 else INT8_QMAX
        codes, scale, zero = _quantize_rows(val, qmax)   # (B, KV[, D])
        if bits == 4:
            payload = _pack_last(codes)
        else:
            payload = codes.astype(jnp.uint8)
        new[name] = pool[name].at[pids, offs].set(payload)
        new[f"{name}_scale"] = pool[f"{name}_scale"].at[pids, offs].set(scale)
        new[f"{name}_zero"] = pool[f"{name}_zero"].at[pids, offs].set(zero)
    return new
