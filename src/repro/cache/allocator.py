"""Free-list page allocator with refcounts, reservations, and a
content-retaining LRU for shared prefix pages.

Invariants (asserted throughout, cheap — all host-side bookkeeping):

* every page id is in exactly ONE of: the free list, the live refcount
  map, or the cached LRU (refcount 0 but content retained for prefix
  reuse);
* ``available() == len(free) + len(cached) - reserved`` never goes
  negative: admission *reserves* its worst-case page count up front
  (``reserve``), then draws the pages down one ``alloc(reserved=True)``
  at a time as the sequence grows — so mid-decode growth can never
  deadlock against other requests;
* a cached page is evicted (oldest first) only when the free list is
  empty; eviction fires ``evict_cb(pid)`` so the prefix store drops its
  key before the content is reused.

The allocator knows nothing about devices or page contents — it hands
out indices into the device pool (``cache/paged.py``); the manager
(``cache/manager.py``) maps requests to pages.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Optional


class OutOfPages(RuntimeError):
    """A page was requested beyond the reserved/available budget."""


class PageAllocator:
    def __init__(self, n_pages: int,
                 evict_cb: Optional[Callable[[int], None]] = None):
        if n_pages < 1:
            raise ValueError(f"need at least one page, got {n_pages}")
        self.n_pages = n_pages
        self.evict_cb = evict_cb
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._refs: dict[int, int] = {}
        self._cached: OrderedDict[int, None] = OrderedDict()  # LRU order
        self.reserved = 0
        # counters (telemetry)
        self.allocs = 0
        self.evictions = 0
        self.peak_live = 0

    # ------------------------------------------------------------------

    @property
    def live_pages(self) -> int:
        return len(self._refs)

    @property
    def free_pages(self) -> int:
        """Pages holding no content at all (excludes the cached LRU)."""
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        return len(self._cached)

    def available(self) -> int:
        """Pages a new reservation could still claim."""
        return len(self._free) + len(self._cached) - self.reserved

    def refcount(self, pid: int) -> int:
        return self._refs.get(pid, 0)

    # ------------------------------------------------------------------

    def can_reserve(self, n: int) -> bool:
        return n <= self.available()

    def reserve(self, n: int):
        if not self.can_reserve(n):
            raise OutOfPages(
                f"reserve({n}) > available {self.available()} "
                f"(pool {self.n_pages}, live {self.live_pages}, "
                f"cached {self.cached_pages}, reserved {self.reserved})")
        self.reserved += n

    def unreserve(self, n: int):
        if n > self.reserved:
            raise AssertionError(
                f"unreserve({n}) > outstanding {self.reserved}")
        self.reserved -= n

    # ------------------------------------------------------------------

    def alloc(self, *, reserved: bool = False) -> int:
        """Claim a fresh page (refcount 1).  ``reserved=True`` draws down
        a prior reservation; otherwise the page must fit in the
        unreserved headroom."""
        if reserved:
            if self.reserved < 1:
                raise AssertionError("alloc(reserved=True) with no "
                                     "outstanding reservation")
            self.reserved -= 1
        elif self.available() < 1:
            raise OutOfPages(
                f"pool exhausted ({self.n_pages} pages, "
                f"{self.live_pages} live, {self.cached_pages} cached, "
                f"{self.reserved} reserved)")
        if self._free:
            pid = self._free.pop()
        else:
            # evict the least-recently-released cached prefix page
            pid, _ = self._cached.popitem(last=False)
            self.evictions += 1
            if self.evict_cb is not None:
                self.evict_cb(pid)
        self._refs[pid] = 1
        self.allocs += 1
        self.peak_live = max(self.peak_live, len(self._refs))
        return pid

    def retain(self, pid: int) -> int:
        """Add a reference: a prefix-share hit on a live page, or the
        resurrection of a cached (refcount-0) one."""
        if pid in self._refs:
            self._refs[pid] += 1
        elif pid in self._cached:
            del self._cached[pid]
            self._refs[pid] = 1
            self.peak_live = max(self.peak_live, len(self._refs))
        else:
            raise AssertionError(f"retain of free page {pid}")
        return self._refs[pid]

    def release(self, pid: int, *, keep_cached: bool = False):
        """Drop a reference.  At refcount 0 the page returns to the free
        list — or, with ``keep_cached`` (a registered complete prefix
        page), to the LRU so an identical future prompt can resurrect
        it."""
        refs = self._refs.get(pid)
        if refs is None:
            raise AssertionError(f"release of non-live page {pid}")
        if refs > 1:
            self._refs[pid] = refs - 1
            return
        del self._refs[pid]
        if keep_cached:
            self._cached[pid] = None
            self._cached.move_to_end(pid)
        else:
            self._free.append(pid)

    def drop_cached(self, pid: int):
        """Forget a cached page outright (manager reset)."""
        if pid in self._cached:
            del self._cached[pid]
            self._free.append(pid)

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        return {
            "total": self.n_pages,
            "live": self.live_pages,
            "cached": self.cached_pages,
            "free": self.free_pages,
            "reserved": self.reserved,
            "peak_live": self.peak_live,
            "allocs": self.allocs,
            "evictions": self.evictions,
        }
