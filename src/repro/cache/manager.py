"""PagedCacheManager — host-side page tables bridging scheduler slots to
the device page pool.

Lifecycle per request (driven by ``runtime/scheduler.py``):

* ``admit(slot, prompt, max_new)`` — reserve the worst-case page count
  (``ceil((plen + max_new - 1) / ps)`` minus prefix-shared pages) so
  mid-decode growth can never deadlock, retain every complete shared
  prefix page, allocate + register the owned full prompt pages, and
  return ``fed0``: the first prompt position this slot must actually
  feed (shared complete pages are skipped — their K/V already exists —
  capped at ``plen - 1`` so the last prompt token always runs and
  yields the first logits).
* ``ensure(slot, pos)`` — before each decode step: allocate the page
  ``pos`` scatters into if the table doesn't cover it yet (drawing down
  the admission reservation).
* ``advance(slot, fed)`` — after each step: mark owned prompt pages
  complete once fully written, making them shareable.
* ``release(slot)`` — retire: return the unused reservation, drop one
  reference per page; refcount-0 pages go back to the free list, except
  registered complete prefix pages which park in the allocator's LRU so
  an identical future prompt can resurrect them (evicted only under
  pressure).

The page table itself is a dense ``(max_batch, pmax)`` int32 array
(``table()``) handed to the jitted decode each step.  The device pool
carries ONE extra physical page (``pool_pages == n_pages + 1``) the
allocator never hands out: the *scratch* page.  Unallocated table
entries point at it (hidden by the position mask on gather), and —
crucially — idle lanes of the fixed-shape decode program scatter their
dummy token there.  Without it an empty slot's table row would alias a
live page (the allocator hands out page 0 first) and every idle step
would corrupt that page's first K/V row.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.cache.allocator import PageAllocator
from repro.cache.prefix import PrefixStore, chain_keys
from repro.cache.spec import PageSpec


@dataclasses.dataclass
class _SlotPages:
    pages: list            # pids, table order (index i covers tokens
                           # [i * ps, (i + 1) * ps))
    full_prompt: int       # prompt full-page count (shareable prefix run)
    shared: int            # leading pages retained from the prefix store
    reserved_left: int     # admission reservation not yet drawn down
    next_complete: int     # first owned prompt page not yet complete


class PagedCacheManager:
    def __init__(self, spec: PageSpec, *, max_batch: int, max_seq: int,
                 n_pages: int = None):
        if not spec.paged:
            raise ValueError("PagedCacheManager needs a paged PageSpec")
        self.spec = spec
        self.page_size = spec.page_size
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.pmax = spec.pages_for(max_seq)
        # default pool == dense worst case (B slots x full-length rows):
        # paging is then strictly better under sharing, never worse
        self.n_pages = n_pages if n_pages else max_batch * self.pmax
        # physical page n_pages is the scratch page (see module docstring)
        self.scratch = self.n_pages
        self.alloc = PageAllocator(self.n_pages, evict_cb=self._on_evict)
        self.prefix = PrefixStore()
        self._tables = np.full((max_batch, self.pmax), self.scratch,
                               np.int32)
        self._slots: dict[int, _SlotPages] = {}
        # (actual, fp-equivalent) bytes per page; set by the scheduler
        # once the device pool exists (layer dims live there)
        self.page_bytes = 0
        self.page_bytes_fp = 0

    def _on_evict(self, pid: int):
        self.prefix.unregister(pid)

    # ------------------------------------------------------------------

    @property
    def pool_pages(self) -> int:
        """Physical pages the device pool must hold: the allocatable
        ``n_pages`` plus the trailing scratch page idle decode lanes
        scatter into."""
        return self.n_pages + 1

    def pages_needed(self, plen: int, max_new: int) -> int:
        """Worst-case pages one request can touch: positions
        ``0 .. plen + max_new - 2`` get written (the final sampled token
        is never fed back)."""
        return self.spec.pages_for(plen + max_new - 1)

    def can_admit(self, plen: int, max_new: int, *,
                  pending_pages: int = 0) -> bool:
        """Conservative (sharing ignored) admission check; the manager
        may admit on less once shared pages are credited."""
        return self.alloc.can_reserve(self.pages_needed(plen, max_new)
                                      + pending_pages)

    def admit(self, slot: int, prompt: np.ndarray, max_new: int) -> int:
        """Bind a request to ``slot``; returns ``fed0`` (see module
        docstring).  Raises ``OutOfPages`` if the worst case (minus
        shared pages) doesn't fit — callers gate on ``can_admit``."""
        assert slot not in self._slots, f"slot {slot} already bound"
        plen = int(prompt.size)
        worst = self.pages_needed(plen, max_new)
        keys = chain_keys(prompt, self.page_size)

        shared = []
        for key in keys:
            pid = self.prefix.lookup(key)
            if pid is None:
                break
            shared.append(pid)
        m, full = len(shared), len(keys)
        self.alloc.reserve(worst - m)
        for pid in shared:
            self.alloc.retain(pid)
        self.prefix.hits += m
        self.prefix.misses += full - m

        sp = _SlotPages(pages=list(shared), full_prompt=full, shared=m,
                        reserved_left=worst - m, next_complete=m)
        # owned full prompt pages: allocated (and keyed) up front so a
        # concurrent identical prompt can find + share them on completion
        for i in range(m, full):
            pid = self.alloc.alloc(reserved=True)
            sp.reserved_left -= 1
            self.prefix.register(pid, keys[i])
            sp.pages.append(pid)
        self._slots[slot] = sp
        self._tables[slot, :len(sp.pages)] = sp.pages
        return min(m * self.page_size, plen - 1)

    def ensure(self, slot: int, pos: int):
        """Guarantee the page covering ``pos`` exists before the scatter."""
        sp = self._slots[slot]
        idx = pos // self.page_size
        while len(sp.pages) <= idx:
            pid = self.alloc.alloc(reserved=True)
            sp.reserved_left -= 1
            self._tables[slot, len(sp.pages)] = pid
            sp.pages.append(pid)

    def advance(self, slot: int, fed: int):
        """``fed`` tokens are now in the cache: owned prompt pages whose
        last position was just written become shareable."""
        sp = self._slots[slot]
        while (sp.next_complete < sp.full_prompt
               and fed >= (sp.next_complete + 1) * self.page_size):
            self.prefix.mark_complete(sp.pages[sp.next_complete])
            sp.next_complete += 1

    def release(self, slot: int):
        """Retire the slot: refund the unused reservation and drop this
        request's reference on every page."""
        sp = self._slots.pop(slot, None)
        if sp is None:
            return
        self.alloc.unreserve(sp.reserved_left)
        for pid in sp.pages:
            if self.prefix.is_complete(pid):
                self.alloc.release(pid, keep_cached=True)
            else:
                # an owned prompt page that never completed (cancel
                # mid-prompt) is unshareable: drop its key with it
                if self.alloc.refcount(pid) == 1:
                    self.prefix.unregister(pid)
                self.alloc.release(pid)
        self._tables[slot] = self.scratch

    # ------------------------------------------------------------------

    def table(self) -> np.ndarray:
        """The (max_batch, pmax) int32 page table the decode step takes."""
        return self._tables

    def slot_pages(self, slot: int) -> int:
        sp = self._slots.get(slot)
        return len(sp.pages) if sp else 0

    @property
    def live_slots(self) -> int:
        return len(self._slots)

    def reset(self):
        """Drop everything, including the retained prefix LRU (the
        device pool is being released)."""
        assert not self._slots, "reset with live slots"
        for pid in list(self.prefix._by_pid):
            self.prefix.unregister(pid)
            self.alloc.drop_cached(pid)
        self._tables[:] = self.scratch

    def stats(self) -> dict:
        a = self.alloc.stats()
        out = {
            "spec": self.spec.shorthand(),
            "page_size": self.page_size,
            "pages": a,
            "prefix": self.prefix.stats(),
            "per_slot_pages": {int(s): len(sp.pages)
                               for s, sp in sorted(self._slots.items())},
        }
        if self.page_bytes:
            out["bytes"] = {
                "per_page": self.page_bytes,
                "pool": self.page_bytes * self.n_pages,
                "live": self.page_bytes * a["live"],
                "peak_live": self.page_bytes * a["peak_live"],
                "dense_equiv": (self.page_bytes_fp * self.pmax
                                * self.max_batch),
                "saved_quantized": ((self.page_bytes_fp - self.page_bytes)
                                    * self.n_pages),
                "saved_prefix": self.page_bytes * self.prefix.hits,
            }
        return out
