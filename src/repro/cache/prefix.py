"""Prefix sharing: content-addressed prompt pages via chained hashes.

A prompt's cacheable unit is a FULL page of prompt tokens.  Page ``i``'s
key is ``hash(key_{i-1} || tokens[i*ps : (i+1)*ps])`` — chaining makes
the key a commitment to the *entire* prefix, so two prompts share page
``i`` iff their first ``(i+1) * ps`` tokens are identical.  K/V entries
are position-dependent but a shared page always holds the same tokens at
the same positions, so its contents are identical across sharers —
writes into shared pages are idempotent, which is what makes concurrent
sharing (and replay-skip over complete pages) safe without any actual
copy; see DESIGN.md §9 for the full copy-on-write protocol.

A page becomes *complete* (lookupable) once its last position has been
written; incomplete registrations exist so the owner can be found for
completion marking, but ``lookup`` never returns them — a request racing
an unfinished identical prompt simply allocates its own pages.
"""

from __future__ import annotations

import hashlib
from typing import Optional

import numpy as np


def chain_keys(tokens: np.ndarray, page_size: int) -> list[bytes]:
    """One chained key per FULL page of ``tokens`` (the ragged tail page
    is never shareable — its contents keep changing as decode appends)."""
    tokens = np.asarray(tokens, np.int64)
    keys = []
    h = b"kv-prefix-v1"
    for i in range(tokens.size // page_size):
        page = tokens[i * page_size:(i + 1) * page_size]
        h = hashlib.sha1(h + page.tobytes()).digest()
        keys.append(h)
    return keys


class PrefixStore:
    """key <-> page-id registry with completion state + hit counters."""

    def __init__(self):
        self._by_key: dict[bytes, int] = {}
        self._by_pid: dict[int, tuple[bytes, bool]] = {}  # pid -> (key, done)
        self.hits = 0            # pages resolved to an existing complete page
        self.misses = 0          # full prompt pages that had to be allocated

    def lookup(self, key: bytes) -> Optional[int]:
        """Page id holding this exact prefix page, if complete."""
        pid = self._by_key.get(key)
        if pid is None or not self._by_pid[pid][1]:
            return None
        return pid

    def register(self, pid: int, key: bytes):
        """Claim ``key`` for a page being filled (incomplete).  First
        writer wins: a key already registered (complete or in flight)
        is left alone and the new page stays anonymous."""
        if key in self._by_key or pid in self._by_pid:
            return
        self._by_key[key] = pid
        self._by_pid[pid] = (key, False)

    def mark_complete(self, pid: int):
        ent = self._by_pid.get(pid)
        if ent is not None:
            self._by_pid[pid] = (ent[0], True)

    def is_registered(self, pid: int) -> bool:
        return pid in self._by_pid

    def is_complete(self, pid: int) -> bool:
        ent = self._by_pid.get(pid)
        return ent is not None and ent[1]

    def unregister(self, pid: int):
        ent = self._by_pid.pop(pid, None)
        if ent is not None:
            self._by_key.pop(ent[0], None)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "registered": len(self._by_key),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hits / total, 4) if total else 0.0,
        }
