"""PageSpec — the KV/state cache half of the deployment plan.

Mirrors ``comm.CollectiveSpec``: a tiny frozen, hashable record with a
string shorthand, parsed once at config time and carried on
``ExecutionPolicy.kv`` so the scheduler, the serving loop, and the
``DeploymentArtifact`` manifest all read one source of truth.

Shorthands::

    dense             no paging: one max_seq-length cache row per slot
    paged:16          16-token pages, bf16 payload
    paged:16:int8     16-token pages, blockwise-int8 quantized payload
    paged:64:int4     64-token pages, nibble-packed int4 payload

Quantized pages reuse ``core/quantization``'s asymmetric min/max scheme
per (token, head) row over head_dim (see ``cache/paged.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union


@dataclasses.dataclass(frozen=True)
class PageSpec:
    """How decode cache memory is laid out for one deployment.

    ``page_size is None`` — dense per-slot rows (the historical layout).
    Otherwise the KV store is a shared pool of ``page_size``-token pages
    indexed through per-slot page tables, with ``bits`` selecting the
    page payload: None (bf16), 8 (uint8 codes + f32 scale/zero per
    token-head row) or 4 (uint32 nibble-packed codes).
    """

    page_size: Optional[int] = None
    bits: Optional[int] = None

    def __post_init__(self):
        if self.page_size is not None and self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.bits is not None:
            if self.page_size is None:
                raise ValueError("kv bits require a page size (quantized "
                                 "pages are a paged-cache feature)")
            if self.bits not in (8, 4):
                raise ValueError(f"kv bits must be 8 or 4, got {self.bits}")

    @property
    def paged(self) -> bool:
        return self.page_size is not None

    def pages_for(self, tokens: int) -> int:
        """Pages covering ``tokens`` cache positions (ceil division)."""
        if not self.paged:
            raise ValueError("dense cache has no pages")
        return max(0, -(-int(tokens) // self.page_size))

    def shorthand(self) -> str:
        if not self.paged:
            return "dense"
        if self.bits is None:
            return f"paged:{self.page_size}"
        return f"paged:{self.page_size}:int{self.bits}"

    @classmethod
    def parse(cls, value: Union["PageSpec", str, None]) -> "PageSpec":
        if value is None:
            return cls()
        if isinstance(value, PageSpec):
            return value
        parts = str(value).split(":")
        if parts[0] == "dense":
            if len(parts) != 1:
                raise ValueError(f"malformed kv spec {value!r}")
            return cls()
        if parts[0] != "paged" or len(parts) not in (2, 3):
            raise ValueError(
                f"unknown kv spec {value!r}, expected 'dense', "
                "'paged:<page_size>' or 'paged:<page_size>:int{8,4}'")
        try:
            page_size = int(parts[1])
        except ValueError:
            raise ValueError(
                f"malformed page size in kv spec {value!r}") from None
        bits = None
        if len(parts) == 3:
            if not parts[2].startswith("int"):
                raise ValueError(f"malformed kv bits in spec {value!r}")
            try:
                bits = int(parts[2][3:])
            except ValueError:
                raise ValueError(
                    f"malformed kv bits in spec {value!r}") from None
        return cls(page_size=page_size, bits=bits)
