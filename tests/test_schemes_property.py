"""Hypothesis property tests for the deployment schemes.

Kept apart from ``test_schemes.py`` so the deterministic suite runs
without the optional ``hypothesis`` dependency (``requirements-dev.txt``
installs it; ``pytest.importorskip`` skips this module when absent).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core import reorder, schemes
from repro.core.policy import DEFAULT_POLICY

from test_schemes import _mk_pair


@given(
    k1g=st.integers(2, 4), n1g=st.integers(2, 6), n2=st.integers(8, 64),
    gsp=st.integers(4, 6), scheme=st.sampled_from(reorder.SCHEMES),
    gate=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_scheme_equivalence_property(k1g, n1g, n2, gsp, scheme, gate):
    gs = 2 ** gsp
    k1, n1 = k1g * gs, n1g * gs
    pp, x, _ = _mk_pair(k1g * 7 + n1g, k1, n1, n2, gs, scheme, gate)
    ppn, xn, _ = _mk_pair(k1g * 7 + n1g, k1, n1, n2, gs, "naive-actorder",
                          gate)
    y = np.asarray(schemes.pair_forward_reference(x, pp, activation="silu"))
    yn = np.asarray(schemes.pair_forward_reference(xn, ppn,
                                                   activation="silu"))
    scale = max(np.abs(yn).max(), 1.0)
    np.testing.assert_allclose(y, yn, atol=3e-4 * scale)


@given(
    k1g=st.integers(2, 4), n1g=st.integers(2, 4), n2=st.integers(8, 48),
    gsp=st.integers(4, 5), scheme=st.sampled_from(reorder.SCHEMES),
    gate=st.booleans(), act=st.sampled_from(["silu", "gelu", None]),
)
@settings(max_examples=12, deadline=None)
def test_forward_default_policy_matches_explicit_property(
        k1g, n1g, n2, gsp, scheme, gate, act):
    """``PlannedPair.forward`` under the default policy is bit-exactly the
    fully-spelled-out policy path, for any shape/scheme/activation draw."""
    gs = 2 ** gsp
    k1, n1 = k1g * gs, n1g * gs
    pp, x, _ = _mk_pair(k1g * 11 + n1g, k1, n1, n2, gs, scheme, gate)
    y_new = np.asarray(pp.forward(x, DEFAULT_POLICY, activation=act))
    y_explicit = np.asarray(schemes.pair_forward_reference(
        x, pp, DEFAULT_POLICY.with_(scheme=scheme, backend="jnp",
                                    collective="psum"),
        activation=act))
    np.testing.assert_array_equal(y_new, y_explicit)
