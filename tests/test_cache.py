"""Paged KV/state cache subsystem (repro.cache, DESIGN.md §9).

Covers: PageSpec parsing, allocator invariants, chained prefix keys,
paged-vs-dense decode bit-identity per family and page size (including a
non-dividing one), quantized page round-trip error bounds, scheduler
integration (paged bit-identity, prefix sharing, pool exhaustion
queueing), and the idle cache-release lifecycle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (OutOfPages, PageAllocator, PagedCacheManager,
                         PageSpec, chain_keys)
from repro.cache import paged as paged_pool
from repro.cache.prefix import PrefixStore
from repro.configs import get_smoke_config
from repro.models.common import REPLICATED
from repro.models.registry import build_model
from repro.runtime import sampling
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.serve import make_engine

GREEDY = sampling.SamplingConfig(temperature=0.0)


# ---------------------------------------------------------------------------
# PageSpec
# ---------------------------------------------------------------------------

def test_page_spec_parse_and_shorthand():
    assert PageSpec.parse(None) == PageSpec()
    assert PageSpec.parse("dense") == PageSpec()
    assert PageSpec.parse("paged:16") == PageSpec(page_size=16)
    assert PageSpec.parse("paged:8:int4") == PageSpec(page_size=8, bits=4)
    for spec in (PageSpec(), PageSpec(page_size=16),
                 PageSpec(page_size=64, bits=8)):
        assert PageSpec.parse(spec.shorthand()) == spec
    assert PageSpec(page_size=5).pages_for(11) == 3
    assert PageSpec(page_size=5).pages_for(10) == 2
    for bad in ("paged", "paged:x", "paged:8:int3", "paged:8:fp8",
                "dense:8", "rows"):
        with pytest.raises(ValueError):
            PageSpec.parse(bad)
    with pytest.raises(ValueError):
        PageSpec(bits=8)            # bits without a page size
    with pytest.raises(ValueError):
        PageSpec(page_size=0)


def test_policy_carries_page_spec():
    from repro.core.policy import ExecutionPolicy

    pol = ExecutionPolicy(kv="paged:16:int8")
    assert pol.kv == PageSpec(page_size=16, bits=8)
    assert ExecutionPolicy().kv == PageSpec()
    cfg = get_smoke_config("qwen3-4b").with_quant(
        mode="mlp", kv_page_size=4, kv_bits=8)
    assert ExecutionPolicy.from_config(cfg).kv == PageSpec(page_size=4,
                                                           bits=8)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_allocator_free_list_and_refcounts():
    a = PageAllocator(4)
    pids = [a.alloc() for _ in range(4)]
    assert len(set(pids)) == 4 and a.free_pages == 0
    with pytest.raises(OutOfPages):
        a.alloc()
    a.retain(pids[0])
    a.release(pids[0])
    assert a.refcount(pids[0]) == 1      # still held once
    a.release(pids[0])
    assert a.refcount(pids[0]) == 0 and a.free_pages == 1
    assert a.peak_live == 4


def test_allocator_reservations_prevent_deadlock():
    a = PageAllocator(4)
    a.reserve(3)
    assert a.available() == 1
    assert not a.can_reserve(2)          # headroom accounts reservations
    with pytest.raises(OutOfPages):
        a.reserve(2)
    # draw the reservation down one page at a time
    got = [a.alloc(reserved=True) for _ in range(3)]
    assert len(got) == 3 and a.reserved == 0
    a.unreserve(0)
    with pytest.raises(AssertionError):
        a.unreserve(1)                   # nothing outstanding
    with pytest.raises(AssertionError):
        a.alloc(reserved=True)


def test_allocator_cached_lru_eviction_order():
    evicted = []
    a = PageAllocator(3, evict_cb=evicted.append)
    p0, p1, p2 = (a.alloc() for _ in range(3))
    a.release(p0, keep_cached=True)      # oldest cached
    a.release(p1, keep_cached=True)
    assert a.cached_pages == 2 and a.available() == 2
    # resurrect p1: it leaves the LRU with content intact
    a.retain(p1)
    assert a.cached_pages == 1 and a.refcount(p1) == 1
    # pool pressure: the free list is empty, so the oldest cached page
    # (p0) is evicted and the prefix layer notified
    p3 = a.alloc()
    assert p3 == p0 and evicted == [p0]
    assert a.evictions == 1


# ---------------------------------------------------------------------------
# prefix keys / store
# ---------------------------------------------------------------------------

def test_chain_keys_commit_to_entire_prefix():
    toks = np.arange(10, dtype=np.int32)
    keys = chain_keys(toks, 4)
    assert len(keys) == 2                # ragged tail page has no key
    # same leading tokens -> same chain; any earlier change reshuffles
    # every later key
    assert chain_keys(toks[:8], 4) == keys
    other = toks.copy()
    other[0] += 1
    keys2 = chain_keys(other, 4)
    assert keys2[0] != keys[0] and keys2[1] != keys[1]
    same_tail = np.concatenate([other[:4], toks[4:]])
    assert chain_keys(same_tail, 4)[1] != keys[1]
    assert chain_keys(toks, 16) == []


def test_prefix_store_lookup_only_complete():
    ps = PrefixStore()
    ps.register(7, b"key")
    assert ps.lookup(b"key") is None     # incomplete: not shareable yet
    ps.mark_complete(7)
    assert ps.lookup(b"key") == 7
    ps.register(8, b"key")               # first writer wins
    assert ps.lookup(b"key") == 7
    ps.unregister(7)
    assert ps.lookup(b"key") is None


# ---------------------------------------------------------------------------
# paged decode == dense decode, bit for bit
# ---------------------------------------------------------------------------

def _paired_decode(arch, page_size, max_seq=15, batch=2, steps=None):
    """Run dense and paged decode side by side; returns final logits."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (batch, steps or max_seq)).astype(np.int32)

    spec = PageSpec(page_size=page_size)
    mgr = PagedCacheManager(spec, max_batch=batch, max_seq=max_seq)
    dense = model.init_cache(batch, max_seq)
    pool = model.init_paged_cache(batch, mgr.pool_pages, page_size)
    for i in range(batch):
        mgr.admit(i, toks[i, :1], max_seq)

    ld = lp = None
    for t in range(steps or max_seq):
        pos = jnp.full((batch,), t, jnp.int32)
        for i in range(batch):
            mgr.ensure(i, t)
        table = jnp.asarray(mgr.table())
        tok = jnp.asarray(toks[:, t])
        ld, dense = model.decode_step(params, dense, tok, pos, REPLICATED)
        lp, pool = model.decode_step(params, pool, tok, pos, REPLICATED,
                                     pages=table)
    return np.asarray(ld), np.asarray(lp)


@pytest.mark.parametrize("page_size", [1, 16, 5])
def test_paged_decode_bit_identical_transformer(page_size):
    """fp paged decode == dense decode bit-for-bit: the masked gather
    tail scores -1e30 whose exp underflows to exactly 0.0, so padded
    pages never contribute — at page size 1, 16 (> some prompts), and a
    max_seq-non-dividing 5."""
    ld, lp = _paired_decode("qwen3-4b", page_size)
    np.testing.assert_array_equal(ld, lp)


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b",
                                  "whisper-large-v3",
                                  "llama-3.2-vision-90b"])
def test_paged_decode_bit_identical_families(arch):
    """Every paged-capable family decodes bit-identically through its
    page pool (whisper/vlm: paged self-attn next to dense cross K/V)."""
    ld, lp = _paired_decode(arch, 4, max_seq=8)
    np.testing.assert_array_equal(ld, lp)


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "rwkv6-3b"])
def test_recurrent_families_ignore_pages(arch):
    """rglru/rwkv6 state is fixed-size per slot — decode accepts the
    pages kwarg (interface uniformity) and ignores it, and the registry
    refuses to build a pool for them."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    assert not model.supports_paged
    with pytest.raises(ValueError, match="no paged cache"):
        model.init_paged_cache(2, 8, 4)
    params = model.init(jax.random.PRNGKey(0))
    cache_a = model.init_cache(2, 12)
    cache_b = model.init_cache(2, 12)
    toks = jnp.asarray([[3, 5], [7, 9]], jnp.int32)
    table = jnp.zeros((2, 3), jnp.int32)
    la = lb = None
    for t in range(2):
        pos = jnp.full((2,), t, jnp.int32)
        la, cache_a = model.decode_step(params, cache_a, toks[:, t], pos,
                                        REPLICATED)
        lb, cache_b = model.decode_step(params, cache_b, toks[:, t], pos,
                                        REPLICATED, pages=table)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# quantized pages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,qmax", [(8, paged_pool.INT8_QMAX),
                                       (4, paged_pool.INT4_QMAX)])
def test_quantized_page_round_trip_error_bound(bits, qmax):
    """scatter -> gather through an intN pool dequantizes every stored
    (token, head) row within the asymmetric-grid bound
    (max - min) / (2 * qmax)."""
    n_pages, ps, kv, hd = 6, 4, 2, 16
    pool = paged_pool.init_pool((), n_pages, ps, kv, hd, bits=bits)
    assert paged_pool.pool_bits(pool) == bits
    rng = np.random.default_rng(0)
    b = 3
    pages = jnp.asarray(np.arange(b * 2).reshape(b, 2), jnp.int32)
    stored = []
    for t in range(2 * ps):
        k = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        pool = paged_pool.scatter_token(pool, k, v,
                                        pages, jnp.full((b,), t, jnp.int32))
        stored.append((np.asarray(k), np.asarray(v)))
    gk, gv = paged_pool.gather(pool, pages)
    for t, (k, v) in enumerate(stored):
        for got, ref in ((np.asarray(gk)[:, t], k), (np.asarray(gv)[:, t],
                                                     v)):
            bound = (ref.max(-1) - ref.min(-1)) / (2 * qmax) + 1e-6
            err = np.abs(got - ref).max(-1)
            assert (err <= bound).all(), (bits, t, err.max())


def test_quantized_pool_page_bytes_smaller_than_fp():
    n_pages = 4
    raw = paged_pool.init_pool((3,), n_pages, 8, 2, 16)
    i8 = paged_pool.init_pool((3,), n_pages, 8, 2, 16, bits=8)
    i4 = paged_pool.init_pool((3,), n_pages, 8, 2, 16, bits=4)
    b_raw, fp_raw = paged_pool.pool_page_bytes(raw, n_pages)
    b_i8, fp_i8 = paged_pool.pool_page_bytes(i8, n_pages)
    b_i4, fp_i4 = paged_pool.pool_page_bytes(i4, n_pages)
    assert b_raw == fp_raw == fp_i8 == fp_i4   # same logical values @bf16
    assert b_i4 < b_i8 < b_raw


def test_int4_pool_requires_packable_head_dim():
    with pytest.raises(ValueError, match="head_dim"):
        paged_pool.init_pool((), 2, 4, 2, 12, bits=4)


# ---------------------------------------------------------------------------
# manager + scheduler integration
# ---------------------------------------------------------------------------

def _make_paged_engine(page_size=4, bits=None, max_seq=24):
    cfg = get_smoke_config("qwen3-4b").with_quant(
        mode="mlp", kv_page_size=page_size, kv_bits=bits)
    return make_engine(cfg, jax.random.PRNGKey(0), max_seq=max_seq)


def test_scheduler_paged_bit_identical_and_prefix_shared():
    """Through the scheduler: paged greedy decode reproduces solo
    ``Engine.generate`` bit-for-bit.  Wave 1 fills the prefix store
    (concurrent identical prompts race — pages are incomplete, so both
    replay); wave 2 resurrects the retired pages from the allocator LRU:
    one request shares the full prompt (replay skip), one only the first
    page (divergent tail), and their staggered lengths leave an idle
    decode lane running next to a live one — the scratch-page
    regression."""
    eng = _make_paged_engine()
    assert eng.uses_page_table
    cfg = eng.model.cfg
    rng = np.random.default_rng(3)
    base = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    prompts = {0: base.copy(), 1: base.copy(),
               2: np.concatenate([base[:4],
                                  rng.integers(1, cfg.vocab_size,
                                               3).astype(np.int32)]),
               3: base.copy()}
    max_new = {0: 5, 1: 5, 2: 6, 3: 3}
    sched = Scheduler(eng, max_batch=2, prompt_budget=8, scfg=GREEDY)
    for rid in (0, 1):
        sched.submit(Request(rid=rid, prompt=prompts[rid],
                             max_new_tokens=max_new[rid]))
    sched.run()
    for rid in (2, 3):
        sched.submit(Request(rid=rid, prompt=prompts[rid],
                             max_new_tokens=max_new[rid]))
    done = sched.run()
    for rid, p in prompts.items():
        ref = np.asarray(eng.generate(
            jax.random.PRNGKey(9), {"tokens": jnp.asarray(p)[None]},
            jnp.asarray([p.size]), max_new_tokens=max_new[rid],
            scfg=GREEDY))[0]
        np.testing.assert_array_equal(np.asarray(done[rid].output), ref,
                                      err_msg=f"req {rid}")
    st = sched.cache_stats()
    # rid 3 resurrected both of rid 0's prompt pages, rid 2 the first
    assert st["prefix"]["hits"] >= 3
    assert st["prefix"]["hit_rate"] > 0
    assert st["bytes"]["saved_prefix"] > 0
    assert st["pages"]["live"] == 0      # everything retired


def test_scheduler_paged_quantized_pages_run_and_save_bytes():
    eng = _make_paged_engine(bits=8)
    cfg = eng.model.cfg
    rng = np.random.default_rng(0)
    sched = Scheduler(eng, max_batch=2, prompt_budget=8, scfg=GREEDY)
    for i in range(2):
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=6).astype(np.int32),
            max_new_tokens=4))
    done = sched.run()
    assert all(len(r.output) == 4 for r in done.values())
    st = sched.cache_stats()
    assert st["spec"] == "paged:4:int8"
    assert st["bytes"]["saved_quantized"] > 0
    assert st["bytes"]["per_page"] < st["bytes"]["dense_equiv"] \
        // (sched.manager.pmax * sched.max_batch)


def test_scheduler_pool_exhaustion_queues_not_fails():
    """A pool too small for two concurrent requests admits them one at a
    time: the second waits in the queue (can_admit False) and still
    finishes; a request that can never fit is rejected at submit."""
    eng = _make_paged_engine(page_size=4, max_seq=24)
    cfg = eng.model.cfg
    pmax = PageSpec(page_size=4).pages_for(24)
    # room for exactly one worst-case request
    sched = Scheduler(eng, max_batch=2, prompt_budget=8, scfg=GREEDY,
                      n_pages=pmax)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=16)
        for i in range(2)]
    sched.submit(reqs[0])
    sched.step()
    assert sched.live_slots == 1
    assert not sched.can_admit(reqs[1])      # pool fully reserved
    sched.submit(reqs[1])
    sched.step()
    assert sched.live_slots == 1             # head waits, FIFO kept
    done = sched.run()
    assert sorted(done) == [0, 1]
    assert all(len(r.output) == 16 for r in done.values())
    # a pool smaller than one request's worst case rejects at submit
    tiny = Scheduler(eng, max_batch=2, prompt_budget=8, scfg=GREEDY,
                     n_pages=2)
    with pytest.raises(ValueError, match="never be admitted"):
        tiny.submit(Request(rid=9, prompt=np.zeros(8, np.int32),
                            max_new_tokens=4))   # pages_for(11) == 3 > 2


def test_scheduler_release_cache_lifetime():
    """The decode cache frees once traffic drains (so a long-lived loop
    doesn't pin peak-batch memory) and rebuilds lazily on the next
    request — for both dense and paged modes."""
    for eng in (make_engine(get_smoke_config("qwen3-4b"),
                            jax.random.PRNGKey(0), max_seq=16),
                _make_paged_engine(max_seq=16)):
        cfg = eng.model.cfg
        sched = Scheduler(eng, max_batch=2, prompt_budget=4, scfg=GREEDY)
        assert not sched.release_cache()       # nothing allocated yet
        sched.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                             max_new_tokens=2))
        sched.step()
        assert sched.cache_stats()["allocated"]
        assert not sched.release_cache()       # refuses while live
        sched.run()
        assert sched.release_cache()
        st = sched.cache_stats()
        assert not st["allocated"]
        if sched.manager is not None:
            assert st["pages"]["live"] == 0 and st["pages"]["cached"] == 0
        # traffic returns: the cache rebuilds and serving still works
        sched.submit(Request(rid=1, prompt=np.asarray([4, 5], np.int32),
                             max_new_tokens=2))
        done = sched.run()
        assert len(done[1].output) == 2
        assert sched.cache_stats()["builds"] == 2
