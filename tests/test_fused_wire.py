"""Fused wire-epilogue subsystem (DESIGN.md §10).

Acceptance criteria of the fused-epilogue PR:

* the Pallas wire kernel's ``(payload, scales[, zeros])`` is BIT-identical
  to running the dense dequant-GEMM and then the collective's own
  ``_blockwise_quantize`` helpers — int8 and int4, dividing and
  non-dividing N, across wire block sizes,
* a ``:fused`` spec round-trips through parse/shorthand and refuses
  non-quant strategies,
* ``supports_wire`` gates on exactly (quant spec, tp > 1, ordered layout,
  tileable K); ineligible sites fall back to the plain epilogue with a
  one-line warning instead of erroring at forward time,
* the pallas backends degrade to jnp (warn-once) when K cannot tile the
  grid (the ``ExecutionPolicy.auto`` contract),
* under a real multi-device shard_map, fused vs unfused quant epilogues
  produce bit-identical outputs AND identical measured HLO wire bytes,
* the autotuner marks eligible winning quant sites ``:fused`` and probes
  aux attention V->O folds as (never-fused) sites.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CollectiveSpec, dispatch as comm_dispatch
from repro.comm.wire import wire_params
from repro.core import quantization as qz
from repro.core.policy import ExecutionPolicy
from repro.kernels import dispatch as kdispatch, ops

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def _ordered_ql(k, n, gs, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.1
    return qz.quantize(w, gs, act_order=True).ordered


def _ragged_ql(n=32):
    """An ordered layout with a ragged final group (K=24, gs=16, G=2):
    valid for ``qz.dequantize`` (g_idx gather) but NOT pallas-tileable —
    lcm(16, 8)=16 does not divide 24."""
    r = jax.random.split(jax.random.PRNGKey(9), 3)
    return qz.QuantizedLinear(
        qweight=jax.random.randint(r[0], (3, n), 0, 2**31 - 1,
                                   jnp.int32).astype(jnp.uint32),
        scales=jax.random.uniform(r[1], (2, n), jnp.float32, 0.01, 0.1),
        zeros=jnp.round(jax.random.uniform(r[2], (2, n), jnp.float32,
                                           0.0, 15.0)),
        g_idx=None, group_size=16, kind="ordered")


# ---------------------------------------------------------------------------
# kernel bit-identity vs quantize-after-GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,n,gs,tp,bits,blk", [
    (128, 96, 32, 4, 8, 32),     # int8, N % (tp*blk) != 0 -> odd wire block
    (64, 128, 8, 8, 8, 128),     # int8, block clamped to the chunk
    (128, 96, 32, 2, 4, 32),     # int4, asymmetric + packing
    (256, 256, 64, 2, 4, 16),    # int4, small preferred block
])
def test_fused_payload_bit_identical(k, n, gs, tp, bits, blk):
    """Fused kernel output == blockwise-quantize of the padded dense
    Pallas GEMM output, bit for bit (payload, scales, zeros)."""
    ql = _ordered_ql(k, n, gs)
    m = 16
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))

    n_pad, _, bs = wire_params(n, tp, bits, blk)
    y = ops.dequant_matmul(x, ql)                       # dense pallas GEMM
    y32 = jnp.pad(y.astype(jnp.float32), [(0, 0), (0, n_pad - n)])

    p, s, z = ops.dequant_matmul_wire(x, ql, tp=tp, wire_bits=bits,
                                      wire_block=blk)
    if bits == 8:
        q_ref, s_ref = comm_dispatch._blockwise_quantize(y32, bs)
        assert z is None
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q_ref))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
    else:
        q_ref, s_ref, z_ref = comm_dispatch._blockwise_quantize_int4(y32, bs)
        p_ref = comm_dispatch._pack4_last(q_ref)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
        np.testing.assert_array_equal(np.asarray(z), np.asarray(z_ref))


def test_fused_payload_batched_lead_dims():
    """Leading batch dims flatten/reshape through the wire kernel."""
    ql = _ordered_ql(64, 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 64))
    p, s, z = ops.dequant_matmul_wire(x, ql, tp=2, wire_bits=8,
                                      wire_block=32)
    assert p.shape == (2, 3, 64) and p.dtype == jnp.int8
    assert s.shape == (2, 3, 2) and s.dtype == jnp.float16
    p2, s2, _ = ops.dequant_matmul_wire(x.reshape(6, 64), ql, tp=2,
                                        wire_bits=8, wire_block=32)
    np.testing.assert_array_equal(np.asarray(p).reshape(6, 64),
                                  np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(s).reshape(6, 2),
                                  np.asarray(s2))


# ---------------------------------------------------------------------------
# spec: ':fused' shorthand
# ---------------------------------------------------------------------------

def test_fused_spec_parse_round_trip():
    for short in ("quant-int8:128:fused", "quant-int4:32:fused",
                  "quant-int8:fused", "quant-int4:fused"):
        spec = CollectiveSpec.parse(short)
        assert spec.fused
        assert CollectiveSpec.parse(spec.shorthand()) == spec
    assert CollectiveSpec.parse("quant-int8:fused").block_size == 128
    assert not CollectiveSpec.parse("quant-int8:128").fused


def test_fused_spec_rejects_non_quant():
    with pytest.raises(ValueError, match="only applies to quant"):
        CollectiveSpec(name="psum", fused=True)
    with pytest.raises(ValueError, match="takes no ':' argument"):
        CollectiveSpec.parse("psum:fused")
    with pytest.raises(ValueError, match="too many ':'"):
        CollectiveSpec.parse("quant-int8:128:64:fused")


# ---------------------------------------------------------------------------
# eligibility gate + graceful fallbacks (S1)
# ---------------------------------------------------------------------------

def test_supports_wire_gating():
    ql = _ordered_ql(64, 32, 32)
    q8 = CollectiveSpec.parse("quant-int8:128")
    assert kdispatch.supports_wire(ql, q8, 2)
    assert kdispatch.supports_wire(ql, CollectiveSpec.parse("quant-int4"), 4)
    # tp=1: no ring to feed
    assert not kdispatch.supports_wire(ql, q8, 1)
    # non-quant collective has no wire payload
    assert not kdispatch.supports_wire(ql, CollectiveSpec(name="psum"), 2)
    # naive layout: only the ordered kernel has a wire variant
    naive = qz.quantize(jax.random.normal(jax.random.PRNGKey(0), (64, 32)),
                        32, act_order=True).naive
    assert not kdispatch.supports_wire(naive, q8, 2)
    # untileable K (ragged final group: lcm(16, 8) does not divide 24)
    assert not kdispatch.supports_wire(_ragged_ql(), q8, 2)


def test_pallas_backend_falls_back_on_untileable_k():
    """S1: the pallas backend warns once and runs the jnp kernel when the
    grid cannot tile K, instead of raising at forward time."""
    ql = _ragged_ql()                    # K=24, lcm(16, 8)=16 -> untileable
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 24))
    pol = ExecutionPolicy(backend="pallas")
    kdispatch._FALLBACK_WARNED.clear()
    with pytest.warns(UserWarning, match="falling back to the jnp backend"):
        y = kdispatch.qmatmul(x, ql, pol)
    y_ref = kdispatch.qmatmul(x, ql, ExecutionPolicy(backend="jnp"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    # warn-once: a second call is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        kdispatch.qmatmul(x, ql, pol)


def test_wire_backend_rejected_as_policy_backend():
    ql = _ordered_ql(64, 32, 32)
    x = jnp.zeros((2, 64))
    with pytest.raises(ValueError, match="wire payload"):
        kdispatch.qmatmul(x, ql, ExecutionPolicy(backend="pallas-fused"))


def test_fused_spec_unfusable_site_warns_and_matches_plain():
    """A hand-written ':fused' plan on an ineligible site (tp=1 mesh)
    falls back to the dense GEMM + plain collective, same numbers."""
    from repro.core import reorder, schemes

    r = jax.random.split(jax.random.PRNGKey(4), 3)
    pp = reorder.plan_pair(
        jax.random.normal(r[0], (32, 64)) * 0.1,
        jax.random.normal(r[1], (64, 32)) * 0.1,
        scheme="tp-aware", group_size_up=32, group_size_down=32, rng=r[2])
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
    fused_pol = ExecutionPolicy(collective="quant-int8:128:fused")
    plain_pol = ExecutionPolicy(collective="quant-int8:128")
    schemes._UNFUSABLE_WARNED.clear()
    with pytest.warns(UserWarning, match="cannot serve pair"):
        y_f = schemes.pair_forward_tp(x, pp, mesh, fused_pol)
    y_p = schemes.pair_forward_tp(x, pp, mesh, plain_pol)
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_p))


# ---------------------------------------------------------------------------
# multi-device: bit-identity + wire bytes (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

def test_fused_epilogue_tp_bit_identical_and_same_wire_bytes():
    """Under a real shard_map ring, a ':fused' quant spec produces
    BIT-identical outputs to the unfused spec (same pallas dense GEMM +
    quantize-after), and the lowered HLO moves the same collective
    bytes — the fusion saves HBM traffic, never wire traffic."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import reorder, schemes
        from repro.core.policy import ExecutionPolicy
        from repro.launch import roofline

        r = jax.random.split(jax.random.PRNGKey(0), 3)
        pp = reorder.plan_pair(
            jax.random.normal(r[0], (64, 256)) * 0.1,
            jax.random.normal(r[1], (256, 96)) * 0.1,
            scheme="tp-aware", group_size_up=32, group_size_down=32,
            rng=r[2])
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))

        for tp, short in ((4, "quant-int8:32"), (2, "quant-int4:32")):
            mesh = jax.make_mesh((1, tp), ("data", "model"),
                                 devices=jax.devices()[:tp])
            outs, bytes_ = {}, {}
            for tag, coll in (("plain", short),
                              ("fused", short + ":fused")):
                pol = ExecutionPolicy(backend="pallas", collective=coll)
                fn = lambda xx, pol=pol: schemes.pair_forward_tp(
                    xx, pp, mesh, pol)
                outs[tag] = np.asarray(jax.jit(fn)(x))
                txt = jax.jit(fn).lower(x).compile().as_text()
                bytes_[tag] = roofline.parse_collective_bytes(
                    txt, chips=tp)["total_per_device"]
            np.testing.assert_array_equal(outs["plain"], outs["fused"])
            assert bytes_["plain"] == bytes_["fused"], (short, bytes_)
            assert bytes_["plain"] > 0
            print(f"OK {short} tp={tp} wire_B={bytes_['plain']:.0f}")
    """)
    assert out.count("OK") == 2


# ---------------------------------------------------------------------------
# tuner integration
# ---------------------------------------------------------------------------

def test_tuner_marks_eligible_quant_sites_fused():
    """autotune marks the winning quant spec ':fused' where the wire
    kernel can serve the site, probes aux V->O folds as attn_vo sites
    (never fused), and the artifact round-trips the plan."""
    from repro.configs import get_smoke_config
    from repro.plan import DeploymentArtifact, compiler

    cfg = get_smoke_config("qwen3-4b").with_quant(attn_tp_aware=True)
    art = compiler.prepare(cfg, tp=2, seed=0, autotune=True,
                           tune_budget=10.0)
    sites = {s["path"]: s for s in art.manifest["collective_tuner"]}
    mlp = sites["layers.mlp"]
    assert mlp["kind"] == "pair" and mlp["status"] == "tuned"
    assert mlp["chosen"].endswith(":fused") and mlp["fused"]
    spec = CollectiveSpec.parse(mlp["chosen"])
    assert spec.fused and spec.name.startswith("quant-")
    # the fused shorthand scores as an alias of the unfused winner
    base = spec.with_(fused=False).shorthand()
    cand = mlp["candidates"]
    assert cand[mlp["chosen"]] == cand[base]

    attn = sites["layers.attn"]
    assert attn["kind"] == "attn_vo" and attn["status"] == "tuned"
    assert not attn["fused"] and not attn["chosen"].endswith(":fused")

    # plan entries carry both sites; policy shorthand round-trips
    plan_paths = [p for p, _ in art.manifest["collective_plan"]["entries"]]
    assert plan_paths == ["layers.mlp", "layers.attn"]
    art.validate(cfg=cfg, policy=art.policy(), tp=2)
