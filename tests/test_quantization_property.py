"""Hypothesis property tests for the quantization substrate.

Kept apart from ``test_quantization.py`` so the deterministic suite runs
without the optional ``hypothesis`` dependency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core import quantization as qz


@given(k8=st.integers(1, 8), n=st.integers(1, 17))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_property(k8, n):
    rng = np.random.default_rng(k8 * 100 + n)
    q = rng.integers(0, 16, size=(k8 * 8, n)).astype(np.int32)
    out = qz.unpack_int4(qz.pack_int4(jnp.asarray(q)))
    np.testing.assert_array_equal(np.asarray(out), q)


@given(
    kg=st.integers(2, 6), n=st.integers(4, 24), gs_pow=st.integers(3, 5),
    act=st.booleans(),
)
@settings(max_examples=15, deadline=None)
def test_quantize_roundtrip_property(kg, n, gs_pow, act):
    gs = 2 ** gs_pow
    k = kg * gs
    rng = jax.random.PRNGKey(kg * 1000 + n * 10 + gs_pow)
    w = jax.random.normal(rng, (k, n)) * 3.0
    res = qz.quantize(w, gs, act_order=act, rng=rng)
    # both layouts agree and error is bounded by the per-group scale
    dq = qz.dequantize(res.naive)
    g_idx = np.asarray(res.g_idx)
    bound = np.take(np.asarray(res.naive.scales), g_idx, axis=0) * 0.5 + 1e-5
    assert (np.abs(np.asarray(w - dq)) <= bound).all()
    restored = jnp.zeros_like(dq).at[res.perm].set(qz.dequantize(res.ordered))
    np.testing.assert_array_equal(np.asarray(dq), np.asarray(restored))
