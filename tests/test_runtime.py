"""Serving runtime: engine, sampling, scheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.runtime import sampling
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.serve import make_engine


def test_greedy_sampling_deterministic():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 50))
    cfg = sampling.SamplingConfig(temperature=0.0)
    a = sampling.sample(jax.random.PRNGKey(1), logits, cfg)
    b = sampling.sample(jax.random.PRNGKey(2), logits, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_topk_sampling_stays_in_topk():
    logits = jnp.asarray([[0.0, 5.0, 4.0, -1.0, 3.0]] * 4)
    cfg = sampling.SamplingConfig(temperature=1.0, top_k=3)
    for seed in range(5):
        s = sampling.sample(jax.random.PRNGKey(seed), logits, cfg)
        assert set(np.asarray(s).tolist()) <= {1, 2, 4}


def test_engine_generate_shapes():
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=32)
    inputs = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    out = eng.generate(jax.random.PRNGKey(1), inputs,
                       jnp.asarray([8, 5]), max_new_tokens=4)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab_size


def test_engine_prefill_matches_forward():
    """Prefill-by-decode-replay last logits == full forward logits at the
    prompt's last position (KV-cache correctness through the engine)."""
    from repro.models.common import REPLICATED

    cfg = get_smoke_config("granite-3-8b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0,
                              cfg.vocab_size)
    inputs = {"tokens": toks}
    fwd = eng.model.forward(eng.params, inputs, REPLICATED)
    cache = eng.init_cache(2)
    last, _ = eng.prefill(inputs, cache, jnp.asarray([6, 6]))
    err = float(jnp.abs(last - fwd[:, -1]).max())
    scale = float(jnp.abs(fwd[:, -1]).max())
    assert err < 2e-2 * scale, err / scale


def test_scheduler_drains_and_batches():
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=40)
    sched = Scheduler(eng, max_batch=3, prompt_budget=8,
                      scfg=sampling.SamplingConfig(temperature=0.5,
                                                   top_k=10))
    rng = np.random.default_rng(0)
    for i in range(7):   # 7 requests, batch 3 -> 3 waves
        plen = int(rng.integers(2, 8))
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=3))
    done = sched.run()
    assert sorted(done) == list(range(7))
    assert all(len(r.output) == 3 for r in done.values())
    assert all(r.done for r in done.values())


def test_scheduler_admits_between_decode_steps():
    """Continuous batching: a queued request is admitted into a retired
    slot while other slots are still decoding — and every request's
    greedy output is bit-identical to running it alone (per-slot position
    clocks + the causal mask isolate slots exactly)."""
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=24)
    greedy = sampling.SamplingConfig(temperature=0.0)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 6, 4)]
    new = (2, 8, 3)   # req 0 retires early; req 2 takes its slot

    sched = Scheduler(eng, max_batch=2, prompt_budget=8, scfg=greedy)
    for i, (p, mn) in enumerate(zip(prompts, new)):
        sched.submit(Request(rid=i, prompt=p, max_new_tokens=mn))
    done = sched.run()
    assert sorted(done) == [0, 1, 2]
    assert [len(done[i].output) for i in range(3)] == list(new)
    # the third request entered mid-stream, not after the first wave
    admitted = dict((rid, step) for step, rid in sched.admissions)
    assert admitted[2] > 0
    last_step = max(p.size for p in prompts[:2]) + max(new[:2])
    assert admitted[2] < last_step

    for i, (p, mn) in enumerate(zip(prompts, new)):
        inputs = {"tokens": jnp.asarray(p)[None, :]}
        ref = np.asarray(eng.generate(
            jax.random.PRNGKey(9), inputs, jnp.asarray([p.size]),
            max_new_tokens=mn, scfg=greedy))[0]
        np.testing.assert_array_equal(np.asarray(done[i].output), ref,
                                      err_msg=f"req {i}")


def test_scheduler_vector_pos_matches_scalar_decode():
    """The per-slot position decode program agrees bit-for-bit with the
    scalar-pos program when every slot shares the same clock."""
    cfg = get_smoke_config("granite-3-8b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0,
                              cfg.vocab_size)
    cache_s = eng.init_cache(2)
    cache_v = eng.init_cache(2)
    for t in range(4):
        ls, cache_s = eng._decode(eng.params, cache_s, toks[:, t],
                                  jnp.int32(t))
        lv, cache_v = eng._decode(eng.params, cache_v, toks[:, t],
                                  jnp.full((2,), t, jnp.int32))
    np.testing.assert_array_equal(np.asarray(ls), np.asarray(lv))
    for a, b in zip(jax.tree_util.tree_leaves(cache_s),
                    jax.tree_util.tree_leaves(cache_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scheduler_recurrent_families_continuous_bit_identical():
    """ssm/hybrid are first-class continuous-batching citizens: each
    lane's recurrent state is independent at dim 1, and a re-admitted
    slot's lane is zeroed (``Engine.reset_slot``) — exactly the
    fresh-cache initial condition, so every request's greedy output is
    bit-identical to a solo run even through slot reuse."""
    greedy = sampling.SamplingConfig(temperature=0.0)
    for arch in ("rwkv6-3b", "recurrentgemma-2b"):
        cfg = get_smoke_config(arch)
        eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=24)
        assert eng.supports_continuous, arch
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (5, 6, 4)]
        new = (2, 8, 3)   # req 0 retires early; req 2 reuses its lane
        sched = Scheduler(eng, max_batch=2, prompt_budget=8, scfg=greedy)
        for i, (p, mn) in enumerate(zip(prompts, new)):
            sched.submit(Request(rid=i, prompt=p, max_new_tokens=mn))
        done = sched.run()
        admitted = dict((rid, step) for step, rid in sched.admissions)
        assert admitted[2] > 0, arch     # entered a previously-used lane
        for i, (p, mn) in enumerate(zip(prompts, new)):
            ref = np.asarray(eng.generate(
                jax.random.PRNGKey(9), {"tokens": jnp.asarray(p)[None]},
                jnp.asarray([p.size]), max_new_tokens=mn, scfg=greedy))[0]
            np.testing.assert_array_equal(
                np.asarray(done[i].output), ref,
                err_msg=f"{arch} req {i}")


def test_scheduler_batch_drain_fallback_families():
    """audio/vlm (batch-global cross prefill) still fall back to
    batch-drain and drain the queue."""
    cfg = get_smoke_config("whisper-large-v3")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=24)
    assert not eng.supports_continuous
    sched = Scheduler(eng, max_batch=2, prompt_budget=6,
                      scfg=sampling.SamplingConfig(temperature=0.0))
    rng = np.random.default_rng(0)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=4).astype(np.int32),
            max_new_tokens=2))
    done = sched.run()
    assert sorted(done) == [0, 1, 2]
    assert all(len(r.output) == 2 for r in done.values())


def test_scheduler_rejects_oversized_prompt():
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)
    sched = Scheduler(eng, prompt_budget=4)
    with pytest.raises(ValueError, match="budget"):
        sched.submit(Request(rid=0, prompt=np.zeros(10, np.int32)))


def test_sample_slots_matches_scalar_sample():
    """One row of the per-slot vectorized sampler is bit-identical to
    the scalar ``sample`` path with the same key and params (this is
    what makes HTTP per-request sampling reproduce solo runs)."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 64))
    for t, p, k in ((0.7, 0.9, 0), (1.2, 0.5, 0), (0.9, 1.0, 10),
                    (0.0, 1.0, 0)):
        cfg = sampling.SamplingConfig(temperature=t,
                                      top_k=k or None,
                                      top_p=None if p == 1.0 else p)
        for seed in range(4):
            key = jax.random.PRNGKey(seed)
            a = sampling.sample(key, logits, cfg)
            b = sampling.sample_slots(
                key[None], logits,
                jnp.asarray([t], jnp.float32), jnp.asarray([p],
                                                           jnp.float32),
                jnp.asarray([k], jnp.int32))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{t},{p},{k},{seed}")


def test_scheduler_per_request_params_bit_identical():
    """Concurrent requests with different temperature/top_p/seed each
    reproduce a solo Engine.generate run with the same params."""
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=24)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 6, 4)]
    params = [(0.9, 0.8, 7), (1.3, 0.5, 11), (0.0, None, 3)]
    sched = Scheduler(eng, max_batch=2, prompt_budget=8,
                      scfg=sampling.SamplingConfig(temperature=0.5))
    for i, (p, (t, tp, sd)) in enumerate(zip(prompts, params)):
        sched.submit(Request(rid=i, prompt=p, max_new_tokens=6,
                             temperature=t, top_p=tp, seed=sd))
    done = sched.run()
    for i, (p, (t, tp, sd)) in enumerate(zip(prompts, params)):
        scfg = sampling.SamplingConfig(temperature=t, top_p=tp)
        ref = np.asarray(eng.generate(
            jax.random.PRNGKey(sd), {"tokens": jnp.asarray(p)[None]},
            jnp.asarray([p.size]), max_new_tokens=6, scfg=scfg))[0]
        np.testing.assert_array_equal(np.asarray(done[i].output), ref,
                                      err_msg=f"req {i}")


def test_scheduler_rejects_mixed_family():
    """One scheduler serves one family: a request declaring a different
    family fails loudly instead of silently serializing behind (or in
    front of) batch-drain waves."""
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)
    sched = Scheduler(eng, prompt_budget=8)
    sched.submit(Request(rid=0, prompt=np.zeros(2, np.int32),
                         max_new_tokens=2, family="dense"))
    with pytest.raises(ValueError, match="one Scheduler per family"):
        sched.submit(Request(rid=1, prompt=np.zeros(2, np.int32),
                             max_new_tokens=2, family="audio"))


def test_scheduler_cancel_frees_slot():
    """A cancelled live request retires at the next step boundary and
    its slot admits the next queued request; a cancelled queued request
    never runs."""
    from repro.runtime.scheduler import StepEvent

    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=24)
    sched = Scheduler(eng, max_batch=1, prompt_budget=8,
                      scfg=sampling.SamplingConfig(temperature=0.0))
    rng = np.random.default_rng(0)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=3).astype(np.int32),
            max_new_tokens=10))
    for _ in range(4):          # request 0 holds the only slot
        sched.step()
    assert sched.live_slots == 1
    assert sched.cancel(0)      # live -> retires at next boundary
    assert sched.cancel(2)      # queued -> dropped, never admitted
    assert not sched.cancel(99)
    events = sched.step()
    assert StepEvent(0, None, True, cancelled=True) in events
    assert StepEvent(2, None, True, cancelled=True) in events
    done = sched.run()
    assert sorted(done) == [0, 1, 2]
    assert len(done[1].output) == 10 and done[1].done
    assert done[0].cancelled and len(done[0].output) < 10
    assert done[2].cancelled and done[2].output == []
    admitted = [rid for _, rid in sched.admissions]
    assert admitted == [0, 1]   # 2 was never admitted
