"""Serving runtime: engine, sampling, scheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.runtime import sampling
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.serve import make_engine


def test_greedy_sampling_deterministic():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 50))
    cfg = sampling.SamplingConfig(temperature=0.0)
    a = sampling.sample(jax.random.PRNGKey(1), logits, cfg)
    b = sampling.sample(jax.random.PRNGKey(2), logits, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_topk_sampling_stays_in_topk():
    logits = jnp.asarray([[0.0, 5.0, 4.0, -1.0, 3.0]] * 4)
    cfg = sampling.SamplingConfig(temperature=1.0, top_k=3)
    for seed in range(5):
        s = sampling.sample(jax.random.PRNGKey(seed), logits, cfg)
        assert set(np.asarray(s).tolist()) <= {1, 2, 4}


def test_engine_generate_shapes():
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=32)
    inputs = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    out = eng.generate(jax.random.PRNGKey(1), inputs,
                       jnp.asarray([8, 5]), max_new_tokens=4)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab_size


def test_engine_prefill_matches_forward():
    """Prefill-by-decode-replay last logits == full forward logits at the
    prompt's last position (KV-cache correctness through the engine)."""
    from repro.models.common import REPLICATED

    cfg = get_smoke_config("granite-3-8b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0,
                              cfg.vocab_size)
    inputs = {"tokens": toks}
    fwd = eng.model.forward(eng.params, inputs, REPLICATED)
    cache = eng.init_cache(2)
    last, _ = eng.prefill(inputs, cache, jnp.asarray([6, 6]))
    err = float(jnp.abs(last - fwd[:, -1]).max())
    scale = float(jnp.abs(fwd[:, -1]).max())
    assert err < 2e-2 * scale, err / scale


def test_scheduler_drains_and_batches():
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=40)
    sched = Scheduler(eng, max_batch=3, prompt_budget=8,
                      scfg=sampling.SamplingConfig(temperature=0.5,
                                                   top_k=10))
    rng = np.random.default_rng(0)
    for i in range(7):   # 7 requests, batch 3 -> 3 waves
        plen = int(rng.integers(2, 8))
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=3))
    done = sched.run()
    assert sorted(done) == list(range(7))
    assert all(len(r.output) == 3 for r in done.values())
    assert all(r.done for r in done.values())


def test_scheduler_rejects_oversized_prompt():
    cfg = get_smoke_config("qwen3-4b")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)
    sched = Scheduler(eng, prompt_budget=4)
    with pytest.raises(ValueError, match="budget"):
        sched.submit(Request(rid=0, prompt=np.zeros(10, np.int32)))
