"""CollectiveSpec / comm-dispatch subsystem.

Covers the redesign's acceptance criteria:
* ``CollectiveSpec.parse`` round-trips every registered strategy and its
  parameterized shorthands; unknown names error with the registered list,
* ``psum`` / ``psum_scatter`` specs are bit-exact with the raw ``jax.lax``
  primitives under multi-device shard_map (the pre-redesign path),
* ``cast`` / ``quant-int8`` stay within tolerances scaled to their wire
  dtype,
* ``bytes_on_wire`` matches the ring cost model and shows the compression
  win (quant-int8 ≈ 25% of f32 psum at TP=8).

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (XLA locks the
host device count at first init).
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.comm import CollectiveSpec, dispatch

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ---------------------------------------------------------------------------
# spec / registry (no devices needed)
# ---------------------------------------------------------------------------

def test_registry_seed_strategies():
    assert dispatch.strategies() == (
        "cast", "none", "psum", "psum_scatter", "quant-int4", "quant-int8")


@pytest.mark.parametrize("name", dispatch.strategies())
def test_parse_round_trips_every_strategy(name):
    spec = CollectiveSpec.parse(name)
    assert spec.name == name
    # shorthand() is the inverse of parse()
    assert CollectiveSpec.parse(spec.shorthand()) == spec
    # parse is idempotent on specs
    assert CollectiveSpec.parse(spec) is spec


def test_parse_shorthands():
    assert CollectiveSpec.parse(None) == CollectiveSpec()
    assert CollectiveSpec.parse("psum") == CollectiveSpec(name="psum")
    c = CollectiveSpec.parse("cast")
    assert c.wire_dtype == jnp.dtype(jnp.bfloat16)
    assert CollectiveSpec.parse("cast:float16").wire_dtype == \
        jnp.dtype(jnp.float16)
    q = CollectiveSpec.parse("quant-int8:64")
    assert (q.name, q.block_size, q.bits) == ("quant-int8", 64, 8)
    q4 = CollectiveSpec.parse("quant-int4")
    assert (q4.name, q4.block_size, q4.bits) == ("quant-int4", 32, 4)
    assert CollectiveSpec.parse("quant-int4:16").block_size == 16
    assert CollectiveSpec(name="quant-int4").bits == 4
    with pytest.raises(ValueError, match="takes no ':' argument"):
        CollectiveSpec.parse("psum:4")
    with pytest.raises(TypeError, match="string shorthand"):
        CollectiveSpec.parse(123)


def test_unknown_strategy_lists_registered_names():
    with pytest.raises(ValueError, match="registered strategies.*psum"):
        CollectiveSpec(name="allreduce-fp4")
    with pytest.raises(ValueError, match="quant-int8"):
        dispatch.resolve("nope")


def test_spec_validates_params():
    with pytest.raises(ValueError, match="block_size"):
        CollectiveSpec(name="quant-int8", block_size=0)
    with pytest.raises(ValueError, match="8-bit"):
        CollectiveSpec(name="quant-int8", bits=4)
    with pytest.raises(ValueError, match="4-bit"):
        CollectiveSpec(name="quant-int4", bits=8)
    with pytest.raises(ValueError, match="unknown wire dtype"):
        CollectiveSpec.parse("cast:fp16")
    # hashable (lives inside the jit-static ExecutionPolicy)
    assert hash(CollectiveSpec.parse("quant-int8")) == hash(
        CollectiveSpec(name="quant-int8"))


def test_policy_carries_collective_spec():
    from repro.core.policy import ExecutionPolicy

    pol = ExecutionPolicy(collective="quant-int8:64")
    assert pol.collective == CollectiveSpec(name="quant-int8", block_size=64)
    assert not hasattr(pol, "reduce") and not hasattr(pol, "reduce_dtype")
    with pytest.raises(ValueError, match="registered strategies"):
        ExecutionPolicy(collective="allgather")


# ---------------------------------------------------------------------------
# analytic bytes accounting
# ---------------------------------------------------------------------------

def test_bytes_on_wire_ring_model():
    shape, tp = (8, 4096), 8
    n = 8 * 4096
    psum = CollectiveSpec(name="psum").bytes_on_wire(shape, tp)
    assert psum == pytest.approx(4 * n * 2 * (tp - 1) / tp)
    assert CollectiveSpec(name="psum_scatter").bytes_on_wire(shape, tp) == \
        pytest.approx(psum / 2)
    assert CollectiveSpec.parse("cast").bytes_on_wire(shape, tp) == \
        pytest.approx(psum / 2)     # bf16 wire = half the f32 words
    assert CollectiveSpec(name="none").bytes_on_wire(shape, tp) == 0.0
    for spec in map(CollectiveSpec.parse, dispatch.strategies()):
        assert spec.bytes_on_wire(shape, 1) == 0.0


def test_quant_int8_bytes_quarter_of_psum_at_tp8():
    """The acceptance headline: int8 payloads + f16 scales land at
    ~(1 + 2/block)/4 ≈ 25% of the f32 psum bytes."""
    shape, tp = (8, 8192), 8
    psum = CollectiveSpec(name="psum").bytes_on_wire(shape, tp)
    quant = CollectiveSpec.parse("quant-int8").bytes_on_wire(shape, tp)
    assert quant / psum == pytest.approx((1 + 2 / 128) / 4)
    assert quant / psum <= 0.26
    # the non-tiling fallback is honestly more expensive, never free
    odd = CollectiveSpec.parse("quant-int8").bytes_on_wire((8, 8193), tp)
    assert odd > quant


def test_quant_int4_bytes_eighth_of_psum_at_tp8():
    """Nibble-packed payloads + f16 (scale, zero) pairs land at
    ~(0.5 + 4/block)/4 of the f32 psum bytes (~15.6% at block 32)."""
    shape, tp = (8, 8192), 8
    psum = CollectiveSpec(name="psum").bytes_on_wire(shape, tp)
    quant = CollectiveSpec.parse("quant-int4").bytes_on_wire(shape, tp)
    assert quant / psum == pytest.approx((0.5 + 4 / 32) / 4)
    assert quant < CollectiveSpec.parse("quant-int8").bytes_on_wire(shape, tp)
    # non-tiling output dims fall back to one-phase with nibble padding
    odd = CollectiveSpec.parse("quant-int4").bytes_on_wire((8, 8193), tp)
    assert odd > quant


# ---------------------------------------------------------------------------
# multi-device semantics (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

def test_collectives_vs_lax_primitives_under_shard_map():
    """psum/psum_scatter specs are BIT-exact with the jax.lax primitives
    (the pre-redesign epilogue); cast/quant-int8 meet wire-dtype-scaled
    error bounds; none returns the untouched partials."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.core import compat

        TP = 8
        mesh = jax.make_mesh((TP,), ("model",))
        y = jax.random.normal(jax.random.PRNGKey(0), (TP, 16, 256)) * 3.0

        def close(spec, out_last):
            # per-rank partial = y[rank]; global result keeps the size-1
            # leading dim, squeezed for comparison below
            g = compat.shard_map(
                lambda v: dispatch.apply(v, "model", spec, None),
                mesh=mesh, in_specs=P("model"),
                out_specs=P(None, None, out_last))(y)
            return np.asarray(g, dtype=np.float32)[0]

        ref = np.asarray(jnp.sum(y, axis=0))        # the true reduction
        psum = compat.shard_map(
            lambda v: jax.lax.psum(v, "model"), mesh=mesh,
            in_specs=P("model"), out_specs=P(None, None, None))(y)
        np.testing.assert_array_equal(
            close(CollectiveSpec("psum"), None), np.asarray(psum)[0])
        print("OK psum-bit-exact")

        scat = compat.shard_map(
            lambda v: jax.lax.psum_scatter(
                v, "model", scatter_dimension=2, tiled=True),
            mesh=mesh, in_specs=P("model"),
            out_specs=P(None, None, "model"))(y)
        np.testing.assert_array_equal(
            close(CollectiveSpec("psum_scatter"), "model"),
            np.asarray(scat)[0])
        print("OK psum_scatter-bit-exact")

        # lossy strategies: tolerance scaled to the wire representation —
        # TP rank contributions each rounded once (cast) or quantized
        # twice (quant-int8, 1/254 of the block amplitude per round)
        scale = np.abs(ref).max()
        lossy = {}
        for short in ("cast", "cast:float16"):
            spec = CollectiveSpec.parse(short)
            lossy[short] = (spec, TP * float(jnp.finfo(spec.wire_dtype).eps))
        qspec = CollectiveSpec.parse("quant-int8")
        lossy["quant-int8"] = (qspec, (TP + 1) * 2.0 ** (1 - qspec.bits))
        q4 = CollectiveSpec.parse("quant-int4")
        # asymmetric int4: one step is (max-min)/15 of the block range,
        # paid once per rank contribution plus once for the re-quantized
        # reduction
        lossy["quant-int4"] = (q4, (TP + 1) * 2.0 / 15.0)
        for short, (spec, t) in lossy.items():
            err = np.abs(close(spec, None) - ref).max() / scale
            assert err < t, (short, err, t)
            assert err > 0, short            # genuinely lossy on the wire
            print("OK", short, f"err={err:.1e} < tol={t:.1e}")

        part = close(CollectiveSpec("none"), None)
        np.testing.assert_array_equal(part, np.asarray(y[0]))
        print("OK none-passthrough")
    """)
    assert out.count("OK") == 7


def test_quant_int8_non_tiling_fallback_and_pair_forward():
    """quant-int8 on an output dim that does NOT tile TP (one-phase
    all-gather fallback), plus the full PlannedPair TP forward for every
    strategy against the single-device reference."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.core import compat, reorder
        from repro.core.policy import ExecutionPolicy

        mesh = jax.make_mesh((8,), ("model",))
        y = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 129))
        ref = np.asarray(jnp.sum(y, axis=0))
        out129 = compat.shard_map(
            lambda v: dispatch.apply(
                v, "model", CollectiveSpec.parse("quant-int8"), None),
            mesh=mesh, in_specs=P("model"),
            out_specs=P(None, None, None))(y)
        err = np.abs(np.asarray(out129) - ref).max() / np.abs(ref).max()
        assert err < 8 * 1 / 127.0, err     # one quant round only
        print("OK fallback", f"{err:.1e}")

        rng = jax.random.PRNGKey(0)
        r = jax.random.split(rng, 4)
        k1, n1, n2, m = 128, 256, 128, 16
        pp = reorder.plan_pair(
            jax.random.normal(r[0], (k1, n1)),
            jax.random.normal(r[2], (n1, n2)),
            w_gate=jax.random.normal(r[1], (k1, n1)), scheme="tp-aware",
            group_size_up=32, group_size_down=32, rng=rng)
        x = jax.random.normal(r[3], (m, k1))
        ref = np.asarray(pp.forward(x, activation="silu"))
        tol = {"psum": 1e-5, "psum_scatter": 1e-5, "cast": 2e-2,
               "quant-int8": 5e-2, "quant-int4": 2e-1}
        with mesh:
            for short, t in tol.items():
                pol = ExecutionPolicy(collective=short)
                y = np.asarray(pp.forward(x, pol, mesh, activation="silu"),
                               dtype=np.float32)
                err = np.abs(y - ref).max() / np.abs(ref).max()
                assert err < t, (short, err)
                print("OK pair", short, f"{err:.1e}")
    """)
    assert out.count("OK") == 6


def test_quant_int4_packs_like_the_weights():
    """The int4 collective's wire payload reuses the weight quantizer's
    nibble packing (``pack_int4``): pack->unpack along the last dim is the
    identity, and a non-tiling dim survives the padded fallback."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.comm.dispatch import _pack4_last, _unpack4_last
        from repro.core import compat

        q = jax.random.randint(jax.random.PRNGKey(0), (3, 5, 64), 0, 16)
        packed = _pack4_last(q)
        assert packed.shape == (3, 5, 8) and packed.dtype == jnp.uint32
        np.testing.assert_array_equal(np.asarray(_unpack4_last(packed)),
                                      np.asarray(q))
        print("OK pack-roundtrip")

        mesh = jax.make_mesh((8,), ("model",))
        y = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 130))
        ref = np.asarray(jnp.sum(y, axis=0))
        got = compat.shard_map(
            lambda v: dispatch.apply(
                v, "model", CollectiveSpec.parse("quant-int4"), None),
            mesh=mesh, in_specs=P("model"),
            out_specs=P(None, None, None))(y)
        err = np.abs(np.asarray(got) - ref).max() / np.abs(ref).max()
        assert err < 8 * 2.0 / 15.0, err     # one quant round per rank
        print("OK int4-fallback", f"{err:.1e}")
    """)
    assert out.count("OK") == 2
