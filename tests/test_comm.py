"""CollectiveSpec / comm-dispatch subsystem.

Covers the redesign's acceptance criteria:
* ``CollectiveSpec.parse`` round-trips every registered strategy and its
  parameterized shorthands; unknown names error with the registered list,
* ``psum`` / ``psum_scatter`` specs are bit-exact with the raw ``jax.lax``
  primitives under multi-device shard_map (the pre-redesign path),
* ``cast`` / ``quant-int8`` stay within tolerances scaled to their wire
  dtype,
* ``bytes_on_wire`` matches the ring cost model and shows the compression
  win (quant-int8 ≈ 25% of f32 psum at TP=8).

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (XLA locks the
host device count at first init).
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.comm import CollectiveSpec, dispatch

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ---------------------------------------------------------------------------
# spec / registry (no devices needed)
# ---------------------------------------------------------------------------

def test_registry_seed_strategies():
    assert dispatch.strategies() == (
        "cast", "none", "psum", "psum_scatter", "quant-int4", "quant-int8")


@pytest.mark.parametrize("name", dispatch.strategies())
def test_parse_round_trips_every_strategy(name):
    spec = CollectiveSpec.parse(name)
    assert spec.name == name
    # shorthand() is the inverse of parse()
    assert CollectiveSpec.parse(spec.shorthand()) == spec
    # parse is idempotent on specs
    assert CollectiveSpec.parse(spec) is spec


def test_parse_shorthands():
    assert CollectiveSpec.parse(None) == CollectiveSpec()
    assert CollectiveSpec.parse("psum") == CollectiveSpec(name="psum")
    c = CollectiveSpec.parse("cast")
    assert c.wire_dtype == jnp.dtype(jnp.bfloat16)
    assert CollectiveSpec.parse("cast:float16").wire_dtype == \
        jnp.dtype(jnp.float16)
    q = CollectiveSpec.parse("quant-int8:64")
    assert (q.name, q.block_size, q.bits) == ("quant-int8", 64, 8)
    q4 = CollectiveSpec.parse("quant-int4")
    assert (q4.name, q4.block_size, q4.bits) == ("quant-int4", 32, 4)
    assert CollectiveSpec.parse("quant-int4:16").block_size == 16
    assert CollectiveSpec(name="quant-int4").bits == 4
    with pytest.raises(ValueError, match="takes no ':' argument"):
        CollectiveSpec.parse("psum:4")
    with pytest.raises(TypeError, match="string shorthand"):
        CollectiveSpec.parse(123)


def test_unknown_strategy_lists_registered_names():
    with pytest.raises(ValueError, match="registered strategies.*psum"):
        CollectiveSpec(name="allreduce-fp4")
    with pytest.raises(ValueError, match="quant-int8"):
        dispatch.resolve("nope")


def test_spec_validates_params():
    with pytest.raises(ValueError, match="block_size"):
        CollectiveSpec(name="quant-int8", block_size=0)
    with pytest.raises(ValueError, match="8-bit"):
        CollectiveSpec(name="quant-int8", bits=4)
    with pytest.raises(ValueError, match="4-bit"):
        CollectiveSpec(name="quant-int4", bits=8)
    with pytest.raises(ValueError, match="unknown wire dtype"):
        CollectiveSpec.parse("cast:int7")
    # CLI-friendly dtype aliases canonicalize (and shorthand() prints the
    # full name, so parse round-trips through the canonical form)
    assert CollectiveSpec.parse("cast:bf16") == CollectiveSpec.parse(
        "cast:bfloat16")
    assert CollectiveSpec.parse("cast:fp16").wire_dtype == \
        jnp.dtype(jnp.float16)
    # hashable (lives inside the jit-static ExecutionPolicy)
    assert hash(CollectiveSpec.parse("quant-int8")) == hash(
        CollectiveSpec(name="quant-int8"))


def test_policy_carries_collective_spec():
    from repro.core.policy import ExecutionPolicy

    pol = ExecutionPolicy(collective="quant-int8:64")
    assert pol.collective == CollectiveSpec(name="quant-int8", block_size=64)
    assert not hasattr(pol, "reduce") and not hasattr(pol, "reduce_dtype")
    with pytest.raises(ValueError, match="registered strategies"):
        ExecutionPolicy(collective="allgather")


# ---------------------------------------------------------------------------
# analytic bytes accounting
# ---------------------------------------------------------------------------

def test_bytes_on_wire_ring_model():
    shape, tp = (8, 4096), 8
    n = 8 * 4096
    psum = CollectiveSpec(name="psum").bytes_on_wire(shape, tp)
    assert psum == pytest.approx(4 * n * 2 * (tp - 1) / tp)
    assert CollectiveSpec(name="psum_scatter").bytes_on_wire(shape, tp) == \
        pytest.approx(psum / 2)
    assert CollectiveSpec.parse("cast").bytes_on_wire(shape, tp) == \
        pytest.approx(psum / 2)     # bf16 wire = half the f32 words
    assert CollectiveSpec(name="none").bytes_on_wire(shape, tp) == 0.0
    for spec in map(CollectiveSpec.parse, dispatch.strategies()):
        assert spec.bytes_on_wire(shape, 1) == 0.0


def test_quant_int8_bytes_quarter_of_psum_at_tp8():
    """The acceptance headline: int8 payloads + f16 scales land at
    ~(1 + 2/block)/4 ≈ 25% of the f32 psum bytes."""
    shape, tp = (8, 8192), 8
    psum = CollectiveSpec(name="psum").bytes_on_wire(shape, tp)
    quant = CollectiveSpec.parse("quant-int8").bytes_on_wire(shape, tp)
    assert quant / psum == pytest.approx((1 + 2 / 128) / 4)
    assert quant / psum <= 0.26
    # non-tiling dims pay wire padding + coarser blocks, but stay on the
    # same two-phase ring accounting (the old one-phase fallback charged
    # payload*(tp-1) — tp/2 times the ring — which inflated vs_psum)
    odd = CollectiveSpec.parse("quant-int8").bytes_on_wire((8, 8193), tp)
    assert odd > quant
    assert odd < quant * 1.1          # ring model: close to the tiling cost
    ring = CollectiveSpec.parse("quant-int8").bytes_on_wire((8, 8200), tp)
    assert odd == pytest.approx(ring)  # padded to the next tp multiple


def test_quant_int4_bytes_eighth_of_psum_at_tp8():
    """Nibble-packed payloads + f16 (scale, zero) pairs land at
    ~(0.5 + 4/block)/4 of the f32 psum bytes (~15.6% at block 32)."""
    shape, tp = (8, 8192), 8
    psum = CollectiveSpec(name="psum").bytes_on_wire(shape, tp)
    quant = CollectiveSpec.parse("quant-int4").bytes_on_wire(shape, tp)
    assert quant / psum == pytest.approx((0.5 + 4 / 32) / 4)
    assert quant < CollectiveSpec.parse("quant-int8").bytes_on_wire(shape, tp)
    # non-tiling output dims pad to whole uint32 words per chunk (tp * 8)
    # and stay on the two-phase ring accounting
    odd = CollectiveSpec.parse("quant-int4").bytes_on_wire((8, 8193), tp)
    assert odd > quant
    assert odd < quant * 1.35         # padding + coarser blocks, not (tp-1)x


# ---------------------------------------------------------------------------
# multi-device semantics (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

def test_collectives_vs_lax_primitives_under_shard_map():
    """psum/psum_scatter specs are BIT-exact with the jax.lax primitives
    (the pre-redesign epilogue); cast/quant-int8 meet wire-dtype-scaled
    error bounds; none returns the untouched partials."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.core import compat

        TP = 8
        mesh = jax.make_mesh((TP,), ("model",))
        y = jax.random.normal(jax.random.PRNGKey(0), (TP, 16, 256)) * 3.0

        def close(spec, out_last):
            # per-rank partial = y[rank]; global result keeps the size-1
            # leading dim, squeezed for comparison below
            g = compat.shard_map(
                lambda v: dispatch.apply(v, "model", spec, None),
                mesh=mesh, in_specs=P("model"),
                out_specs=P(None, None, out_last))(y)
            return np.asarray(g, dtype=np.float32)[0]

        ref = np.asarray(jnp.sum(y, axis=0))        # the true reduction
        psum = compat.shard_map(
            lambda v: jax.lax.psum(v, "model"), mesh=mesh,
            in_specs=P("model"), out_specs=P(None, None, None))(y)
        np.testing.assert_array_equal(
            close(CollectiveSpec("psum"), None), np.asarray(psum)[0])
        print("OK psum-bit-exact")

        scat = compat.shard_map(
            lambda v: jax.lax.psum_scatter(
                v, "model", scatter_dimension=2, tiled=True),
            mesh=mesh, in_specs=P("model"),
            out_specs=P(None, None, "model"))(y)
        np.testing.assert_array_equal(
            close(CollectiveSpec("psum_scatter"), "model"),
            np.asarray(scat)[0])
        print("OK psum_scatter-bit-exact")

        # lossy strategies: tolerance scaled to the wire representation —
        # TP rank contributions each rounded once (cast) or quantized
        # twice (quant-int8, 1/254 of the block amplitude per round)
        scale = np.abs(ref).max()
        lossy = {}
        for short in ("cast", "cast:float16"):
            spec = CollectiveSpec.parse(short)
            lossy[short] = (spec, TP * float(jnp.finfo(spec.wire_dtype).eps))
        qspec = CollectiveSpec.parse("quant-int8")
        lossy["quant-int8"] = (qspec, (TP + 1) * 2.0 ** (1 - qspec.bits))
        q4 = CollectiveSpec.parse("quant-int4")
        # asymmetric int4: one step is (max-min)/15 of the block range,
        # paid once per rank contribution plus once for the re-quantized
        # reduction
        lossy["quant-int4"] = (q4, (TP + 1) * 2.0 / 15.0)
        for short, (spec, t) in lossy.items():
            err = np.abs(close(spec, None) - ref).max() / scale
            assert err < t, (short, err, t)
            assert err > 0, short            # genuinely lossy on the wire
            print("OK", short, f"err={err:.1e} < tol={t:.1e}")

        part = close(CollectiveSpec("none"), None)
        np.testing.assert_array_equal(part, np.asarray(y[0]))
        print("OK none-passthrough")
    """)
    assert out.count("OK") == 7


def test_quant_int8_non_tiling_padded_ring_and_pair_forward():
    """quant-int8 on an output dim that does NOT tile TP (zero-padded on
    the wire, same two-phase ring), plus the full PlannedPair TP forward
    for every strategy against the single-device reference."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.core import compat, reorder
        from repro.core.policy import ExecutionPolicy

        mesh = jax.make_mesh((8,), ("model",))
        y = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 129))
        ref = np.asarray(jnp.sum(y, axis=0))
        out129 = compat.shard_map(
            lambda v: dispatch.apply(
                v, "model", CollectiveSpec.parse("quant-int8"), None),
            mesh=mesh, in_specs=P("model"),
            out_specs=P(None, None, None))(y)
        err = np.abs(np.asarray(out129) - ref).max() / np.abs(ref).max()
        # TP rank contributions each rounded once + the re-quantized
        # reduction rounded once (padded two-phase ring numerics)
        assert err < (8 + 1) * 2.0 ** (1 - 8), err
        print("OK padded-ring", f"{err:.1e}")

        rng = jax.random.PRNGKey(0)
        r = jax.random.split(rng, 4)
        k1, n1, n2, m = 128, 256, 128, 16
        pp = reorder.plan_pair(
            jax.random.normal(r[0], (k1, n1)),
            jax.random.normal(r[2], (n1, n2)),
            w_gate=jax.random.normal(r[1], (k1, n1)), scheme="tp-aware",
            group_size_up=32, group_size_down=32, rng=rng)
        x = jax.random.normal(r[3], (m, k1))
        ref = np.asarray(pp.forward(x, activation="silu"))
        tol = {"psum": 1e-5, "psum_scatter": 1e-5, "cast": 2e-2,
               "quant-int8": 5e-2, "quant-int4": 2e-1}
        with mesh:
            for short, t in tol.items():
                pol = ExecutionPolicy(collective=short)
                y = np.asarray(pp.forward(x, pol, mesh, activation="silu"),
                               dtype=np.float32)
                err = np.abs(y - ref).max() / np.abs(ref).max()
                assert err < t, (short, err)
                print("OK pair", short, f"{err:.1e}")
    """)
    assert out.count("OK") == 6


def test_quant_int4_packs_like_the_weights():
    """The int4 collective's wire payload reuses the weight quantizer's
    nibble packing (``pack_int4``): pack->unpack along the last dim is the
    identity, and a non-tiling dim survives the padded two-phase ring."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.comm.dispatch import _pack4_last, _unpack4_last
        from repro.core import compat

        q = jax.random.randint(jax.random.PRNGKey(0), (3, 5, 64), 0, 16)
        packed = _pack4_last(q)
        assert packed.shape == (3, 5, 8) and packed.dtype == jnp.uint32
        np.testing.assert_array_equal(np.asarray(_unpack4_last(packed)),
                                      np.asarray(q))
        print("OK pack-roundtrip")

        mesh = jax.make_mesh((8,), ("model",))
        y = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 130))
        ref = np.asarray(jnp.sum(y, axis=0))
        got = compat.shard_map(
            lambda v: dispatch.apply(
                v, "model", CollectiveSpec.parse("quant-int4"), None),
            mesh=mesh, in_specs=P("model"),
            out_specs=P(None, None, None))(y)
        err = np.abs(np.asarray(got) - ref).max() / np.abs(ref).max()
        # one quant round per rank + the phase-2 re-quantization
        assert err < (8 + 1) * 2.0 / 15.0, err
        print("OK int4-padded-ring", f"{err:.1e}")
    """)
    assert out.count("OK") == 2


# ---------------------------------------------------------------------------
# dtype contract (uniform across every registered strategy)
# ---------------------------------------------------------------------------

def test_tp1_is_noop_with_zero_bytes():
    """At TP=1 every strategy is the identity (bit-exact, any dtype) and
    its analytic wire cost is zero — runs on the single host device."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import compat

    mesh = jax.make_mesh((1,), ("model",), devices=jax.devices()[:1])
    for name in dispatch.strategies():
        spec = CollectiveSpec.parse(name)
        assert spec.bytes_on_wire((4, 96), 1) == 0.0
        for dtype in (jnp.float32, jnp.bfloat16):
            y = jax.random.normal(jax.random.PRNGKey(0), (4, 96)
                                  ).astype(dtype)
            out = compat.shard_map(
                lambda v, spec=spec: dispatch.apply(v, "model", spec, None),
                mesh=mesh, in_specs=P(), out_specs=P())(y)
            assert out.dtype == dtype, (name, out.dtype)
            np.testing.assert_array_equal(np.asarray(out, np.float32),
                                          np.asarray(y, np.float32))


def test_dtype_contract_every_strategy_tp8():
    """Output dtype == input dtype for EVERY strategy at TP=8, for f32
    and bf16 partials alike — wire dtypes (bf16 words, int8/int4
    payloads) must never leak into the caller's residual stream.  This
    is the cast-collective bugfix: it used to return ``wire_dtype``."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.core import compat

        mesh = jax.make_mesh((8,), ("model",))
        for name in dispatch.strategies():
            spec = CollectiveSpec.parse(name)
            out_last = "model" if dispatch.scatters_output(spec) else None
            for dtype in (jnp.float32, jnp.bfloat16):
                y = (jax.random.normal(jax.random.PRNGKey(0), (8, 4, 256))
                     .astype(dtype))
                got = compat.shard_map(
                    lambda v: dispatch.apply(v, "model", spec, None),
                    mesh=mesh, in_specs=P("model"),
                    out_specs=P(None, None, out_last))(y)
                assert got.dtype == dtype, (name, dtype, got.dtype)
            print("OK dtype", name)
    """)
    assert out.count("OK dtype") == len(dispatch.strategies())


def test_measured_bytes_match_analytic_model():
    """The tightened measured-vs-analytic contract: per-device collective
    bytes parsed from the lowered HLO equal ``bytes_on_wire`` EXACTLY for
    psum / psum_scatter / quant-int8 / quant-int4 — on tiling AND
    non-tiling output dims (the old one-phase fallback accounting is
    gone; implementation and model are both the padded two-phase ring).
    ``cast`` is exempt on CPU only: XLA promotes the bf16 all-reduce to
    f32 there (measured = 2x model; the wire stays bf16 on TPU)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.comm import CollectiveSpec, dispatch
        from repro.core import compat
        from repro.launch import roofline

        mesh = jax.make_mesh((8,), ("model",))
        for n in (4096, 129, 8193):
            y = jax.random.normal(jax.random.PRNGKey(0), (8, 4, n))
            for name in ("psum", "psum_scatter", "quant-int8", "quant-int4"):
                spec = CollectiveSpec.parse(name)
                if dispatch.scatters_output(spec) and n % 8:
                    continue        # reduce_scatter needs a tiling dim
                out_last = ("model" if dispatch.scatters_output(spec)
                            else None)
                fn = compat.shard_map(
                    lambda v, spec=spec: dispatch.apply(
                        v, "model", spec, None),
                    mesh=mesh, in_specs=P("model"),
                    out_specs=P(None, None, out_last))
                txt = jax.jit(fn).lower(y).compile().as_text()
                hlo = roofline.parse_collective_bytes(
                    txt, chips=8)["total_per_device"]
                model = spec.bytes_on_wire((4, n), 8)
                rel = abs(hlo - model) / max(model, 1.0)
                assert rel < 1e-6, (name, n, hlo, model)
                print(f"OK bytes {name} n={n}")
    """)
    assert out.count("OK bytes") == 10


# ---------------------------------------------------------------------------
# per-layer CollectivePlan
# ---------------------------------------------------------------------------

def test_collective_plan_parse_roundtrip():
    from repro.comm import CollectivePlan, parse_collective

    short = ("per-layer:*.mlp=quant-int8:128,attn*=cast:bfloat16,"
             "*=psum")
    plan = CollectivePlan.parse(short)
    assert plan.shorthand() == short
    assert CollectivePlan.parse(plan.shorthand()) == plan
    assert parse_collective(short) == plan
    # dtype alias normalizes into the canonical shorthand
    assert CollectivePlan.parse(
        "per-layer:attn*=cast:bf16,*=psum").shorthand() == \
        "per-layer:attn*=cast:bfloat16,*=psum"
    # a bare spec parses as a zero-entry plan; plain shorthands stay specs
    assert CollectivePlan.parse("quant-int8").default == \
        CollectiveSpec.parse("quant-int8")
    assert parse_collective("quant-int8") == CollectiveSpec.parse(
        "quant-int8")
    # hashable: lives on the jit-static ExecutionPolicy
    assert hash(plan) == hash(CollectivePlan.parse(short))


def test_collective_plan_resolve_globs_in_order():
    from repro.comm import CollectivePlan

    plan = CollectivePlan.parse(
        "per-layer:layers.mlp=quant-int4,*.mlp=quant-int8,"
        "*.experts=cast:float16,*=psum")
    assert plan.resolve("layers.mlp").name == "quant-int4"   # first match
    assert plan.resolve("super.self.mlp").name == "quant-int8"
    assert plan.resolve("layers/moe/experts").name == "cast"  # "/" == "."
    assert plan.resolve("layers.attn").name == "psum"
    assert plan.resolve(None) == plan.default                # anonymous site
    # suffix-friendly matching: a bare segment glob hits nested paths
    assert plan.resolve("enc_layers.mlp").name == "quant-int8"
    specs = plan.specs()
    assert len(specs) == 4 and specs[-1] == plan.default


def test_collective_plan_rejects_malformed_shorthand():
    from repro.comm import CollectivePlan

    with pytest.raises(ValueError, match="never match"):
        CollectivePlan.parse("per-layer:*=psum,mlp=cast")
    with pytest.raises(ValueError, match="glob.*=.*spec|not '<glob>"):
        CollectivePlan.parse("per-layer:justaname")
    with pytest.raises(ValueError, match="registered strategies"):
        CollectivePlan.parse("per-layer:*.mlp=warp-speed,*=psum")


def test_policy_accepts_plan_and_spec():
    from repro.comm import CollectivePlan
    from repro.core.policy import ExecutionPolicy

    pol = ExecutionPolicy(
        collective="per-layer:*.mlp=quant-int8:64,*=psum")
    assert isinstance(pol.collective, CollectivePlan)
    assert pol.collective.resolve("layers.mlp").block_size == 64
    hash(pol)                       # still a valid jit static
    # bare specs keep resolving to themselves, path or not
    pol2 = ExecutionPolicy(collective="quant-int8:64")
    assert pol2.collective.resolve("layers.mlp") == pol2.collective


def test_per_layer_plan_resolves_per_pair_and_psum_is_bit_exact():
    """Acceptance: a ``per-layer:*=psum`` plan is BIT-exact with the
    global psum policy, and a mixed plan resolves different strategies
    per pair path — verified structurally via the lowered HLO collective
    counts (quant-int8 epilogue = all_to_all + all_gather phases; psum
    epilogue = one all-reduce)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import reorder
        from repro.core.policy import ExecutionPolicy
        from repro.launch import roofline

        rng = jax.random.PRNGKey(0)
        r = jax.random.split(rng, 4)
        k1, n1, n2, m = 128, 256, 128, 16
        pp = reorder.plan_pair(
            jax.random.normal(r[0], (k1, n1)),
            jax.random.normal(r[2], (n1, n2)),
            w_gate=jax.random.normal(r[1], (k1, n1)), scheme="tp-aware",
            group_size_up=32, group_size_down=32, rng=rng)
        x = jax.random.normal(r[3], (m, k1))
        mesh = jax.make_mesh((8,), ("model",))

        pol_psum = ExecutionPolicy(collective="psum")
        pol_plan = ExecutionPolicy(collective="per-layer:*=psum")
        with mesh:
            y_g = np.asarray(pp.forward(x, pol_psum, mesh,
                                        activation="silu",
                                        pair_path="layers.mlp"))
            y_p = np.asarray(pp.forward(x, pol_plan, mesh,
                                        activation="silu",
                                        pair_path="layers.mlp"))
        np.testing.assert_array_equal(y_g, y_p)
        print("OK per-layer-psum-bit-exact")

        mixed = ExecutionPolicy(collective=
            "per-layer:*.mlp=quant-int8:32,*=psum")
        with mesh:
            for path, want_kind in (("layers.mlp", "all-to-all"),
                                    ("layers.attn", "all-reduce")):
                fn = lambda xx, p, path=path: p.forward(
                    xx, mixed, mesh, activation="silu", pair_path=path)
                txt = jax.jit(fn).lower(x, pp).compile().as_text()
                counts = roofline.parse_collective_bytes(
                    txt, chips=8)["counts"]
                assert counts[want_kind] > 0, (path, counts)
                other = ("all-reduce" if want_kind == "all-to-all"
                         else "all-to-all")
                assert counts[other] == 0, (path, counts)
                print("OK per-layer-hlo", path, want_kind)
    """)
    assert out.count("OK") == 3
