"""HTTP/SSE serving front end (repro.serving, DESIGN.md §8).

One smoke engine is shared module-wide (compiling it dominates test
time); each test builds its own ``ServingServer`` on an ephemeral port
with the queue/batch geometry it needs.
"""

import http.client
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.runtime import sampling
from repro.runtime.serve import make_engine
from repro.serving import ServingServer, tokenize_stub

MAX_SEQ = 64


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen3-4b")
    return make_engine(cfg, jax.random.PRNGKey(0), max_seq=MAX_SEQ)


@pytest.fixture(scope="module")
def paged_engine():
    from repro.cache import PageSpec
    from repro.core.policy import ExecutionPolicy

    cfg = get_smoke_config("qwen3-4b")
    policy = ExecutionPolicy.from_config(cfg).with_(
        kv=PageSpec(page_size=8, bits=8))
    return make_engine(cfg, jax.random.PRNGKey(0), max_seq=MAX_SEQ,
                      policy=policy)


@pytest.fixture()
def paged_server(paged_engine, request):
    params = getattr(request, "param", {})
    srv = ServingServer(paged_engine,
                        max_batch=params.get("max_batch", 2),
                        prompt_budget=params.get("prompt_budget", 16),
                        queue_capacity=params.get("queue_capacity", 4),
                        retry_after=0.25,
                        n_pages=params.get("n_pages"),
                        cache_idle=params.get("cache_idle", 30.0),
                        scfg=sampling.SamplingConfig(temperature=0.0))
    srv.start()
    yield srv
    srv.shutdown(drain=False, timeout=10.0)


@pytest.fixture()
def server(engine, request):
    params = getattr(request, "param", {})
    srv = ServingServer(engine, max_batch=params.get("max_batch", 2),
                        prompt_budget=params.get("prompt_budget", 16),
                        queue_capacity=params.get("queue_capacity", 4),
                        retry_after=0.25,
                        scfg=sampling.SamplingConfig(temperature=0.0))
    srv.start()
    yield srv
    srv.shutdown(drain=False, timeout=10.0)


def _post(port, body, timeout=120.0):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    conn.request("POST", "/v1/generate", json.dumps(body),
                 {"Content-Type": "application/json"})
    return conn, conn.getresponse()


def _events(resp):
    """Parse a full SSE body into [(event, payload_dict), ...]."""
    out, event = [], None
    for raw in resp.read().decode("utf-8").split("\n"):
        if raw.startswith("event: "):
            event = raw[len("event: "):]
        elif raw.startswith("data: "):
            out.append((event, json.loads(raw[len("data: "):])))
    return out


def _get_json(port, path):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read())
    finally:
        conn.close()


# ----------------------------------------------------------------------
# SSE framing + routes
# ----------------------------------------------------------------------

def test_sse_event_framing(server):
    conn, resp = _post(server.port, {"prompt": [1, 2, 3],
                                     "max_new_tokens": 4, "seed": 0})
    assert resp.status == 200
    assert resp.getheader("Content-Type") == "text/event-stream"
    raw = resp.read().decode("utf-8")
    conn.close()
    # every frame is "event: <name>\ndata: <json>\n\n"
    frames = [f for f in raw.split("\n\n") if f]
    kinds = []
    for frame in frames:
        lines = frame.split("\n")
        assert len(lines) == 2, frame
        assert lines[0].startswith("event: ") and \
            lines[1].startswith("data: "), frame
        json.loads(lines[1][len("data: "):])      # valid JSON payload
        kinds.append(lines[0][len("event: "):])
    assert kinds[0] == "start"
    assert kinds[1:-1] == ["token"] * 4
    assert kinds[-1] == "done"
    # token events carry contiguous indices; done carries usage
    payloads = [json.loads(f.split("\n")[1][6:]) for f in frames]
    assert [p["index"] for p in payloads[1:-1]] == [0, 1, 2, 3]
    usage = payloads[-1]["usage"]
    assert usage["prompt_tokens"] == 3
    assert usage["completion_tokens"] == 4
    assert usage["finish_reason"] == "length"
    assert usage["ttft_ms"] > 0


def test_health_and_text_stub(server):
    status, health = _get_json(server.port, "/v1/health")
    assert status == 200 and health["status"] == "ok"
    assert health["arch"] == "qwen3-4b"

    ids = tokenize_stub("hello", 512)
    assert ids.dtype == np.int32 and ids.size == 5

    conn, resp = _post(server.port, {"text": "hi", "max_new_tokens": 2})
    events = _events(resp)
    conn.close()
    assert events[-1][0] == "done"

    for bad in ({}, {"prompt": []}, {"prompt": [1, 999999]},
                {"prompt": [1], "max_new_tokens": 0},
                {"prompt": [1], "top_p": 2.0},
                {"prompt": list(range(40))}):       # > prompt_budget
        conn, resp = _post(server.port, bad)
        assert resp.status == 400, bad
        resp.read()
        conn.close()


# ----------------------------------------------------------------------
# backpressure
# ----------------------------------------------------------------------

@pytest.mark.parametrize("server", [{"max_batch": 1,
                                     "queue_capacity": 1}],
                         indirect=True)
def test_queue_backpressure_429(server):
    # fill the single slot and the single queue seat with long
    # generations, then the next request must be shed with 429
    held = [_post(server.port, {"prompt": [1, 2], "max_new_tokens": 40,
                                "seed": i}, timeout=300)
            for i in range(2)]
    deadline = time.monotonic() + 30
    status = None
    while time.monotonic() < deadline:
        conn, resp = _post(server.port, {"prompt": [3],
                                         "max_new_tokens": 2})
        status = resp.status
        resp.read()
        conn.close()
        if status == 429:
            assert float(resp.getheader("Retry-After")) > 0
            break
        time.sleep(0.02)    # a held request may not have queued yet
    assert status == 429
    _, stats = _get_json(server.port, "/v1/stats")
    assert stats["queue"]["rejected"] >= 1
    assert stats["queue"]["capacity"] == 1
    for conn, resp in held:
        assert _events(resp)[-1][0] == "done"
        conn.close()


# ----------------------------------------------------------------------
# cancellation frees the slot
# ----------------------------------------------------------------------

@pytest.mark.parametrize("server", [{"max_batch": 1,
                                     "queue_capacity": 4}],
                         indirect=True)
def test_client_disconnect_frees_slot(server):
    # request A occupies the ONLY slot with a long generation; read two
    # events then hang up mid-stream
    conn, resp = _post(server.port, {"prompt": [5, 6, 7],
                                     "max_new_tokens": 50, "seed": 1})
    assert resp.status == 200
    got_tokens = 0
    for line in resp:
        if line.startswith(b"data: ") and b"token" in line:
            got_tokens += 1
            if got_tokens >= 2:
                break
    resp.close()              # hang up mid-generation (closes the
    conn.close()              # socket under the half-read SSE stream)

    # the slot must free at the next step boundary: request B (on the
    # same 1-slot engine) completes, and /v1/stats records the cancel
    conn2, resp2 = _post(server.port, {"prompt": [8, 9],
                                       "max_new_tokens": 3, "seed": 2},
                         timeout=60)
    assert resp2.status == 200
    events = _events(resp2)
    conn2.close()
    assert events[-1][0] == "done"
    assert sum(1 for k, _ in events if k == "token") == 3

    deadline = time.monotonic() + 20
    stats = None
    while time.monotonic() < deadline:
        _, stats = _get_json(server.port, "/v1/stats")
        if stats["requests"]["cancelled"] >= 1:
            break
        time.sleep(0.05)
    assert stats["requests"]["cancelled"] == 1
    assert stats["requests"]["in_flight"] == 0
    assert stats["engine"]["live_slots"] == 0
    # the cancelled request was cut well short of its 50 tokens
    assert stats["tokens"]["generated"] < 45


# ----------------------------------------------------------------------
# per-request sampling params == solo Engine.generate
# ----------------------------------------------------------------------

@pytest.mark.parametrize("server", [{"max_batch": 4}], indirect=True)
def test_per_request_params_bit_identical_to_solo(server, engine):
    """Three concurrent HTTP requests with different temperature/top_p/
    seed each produce exactly the tokens of a solo ``Engine.generate``
    run with the same params — per-slot sampling-param vectors and
    per-request PRNG chains isolate requests completely."""
    cfg = engine.model.cfg
    rng = np.random.default_rng(5)
    cases = [
        {"prompt": rng.integers(0, cfg.vocab_size, 6).tolist(),
         "max_new_tokens": 6, "temperature": 0.9, "top_p": 0.8,
         "seed": 7},
        {"prompt": rng.integers(0, cfg.vocab_size, 4).tolist(),
         "max_new_tokens": 8, "temperature": 1.3, "top_p": 0.5,
         "seed": 11},
        {"prompt": rng.integers(0, cfg.vocab_size, 9).tolist(),
         "max_new_tokens": 5, "temperature": 0.0, "seed": 3},
    ]
    results = [None] * len(cases)

    def client(i):
        conn, resp = _post(server.port, cases[i], timeout=300)
        results[i] = [p["token"] for k, p in _events(resp)
                      if k == "token"]
        conn.close()

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(len(cases))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for i, case in enumerate(cases):
        scfg = sampling.SamplingConfig(
            temperature=case["temperature"], top_p=case.get("top_p"))
        prompt = np.asarray(case["prompt"], np.int32)
        ref = np.asarray(engine.generate(
            jax.random.PRNGKey(case["seed"]),
            {"tokens": jnp.asarray(prompt)[None]},
            jnp.asarray([prompt.size]),
            max_new_tokens=case["max_new_tokens"], scfg=scfg))[0]
        np.testing.assert_array_equal(np.asarray(results[i]), ref,
                                      err_msg=f"case {i}")


# ----------------------------------------------------------------------
# stats counters
# ----------------------------------------------------------------------

def test_stats_counters_and_histograms(server):
    for i in range(3):
        conn, resp = _post(server.port, {"prompt": [i + 1, i + 2],
                                         "max_new_tokens": 3, "seed": i})
        assert _events(resp)[-1][0] == "done"
        conn.close()
    _, stats = _get_json(server.port, "/v1/stats")
    assert stats["requests"]["admitted"] == 3
    assert stats["requests"]["completed"] == 3
    assert stats["requests"]["cancelled"] == 0
    assert stats["requests"]["in_flight"] == 0
    assert stats["queue"]["offered"] == 3
    assert stats["queue"]["depth"] == 0
    assert stats["tokens"]["generated"] == 9
    ttft = stats["latency_ms"]["ttft"]
    itl = stats["latency_ms"]["itl"]
    assert ttft["count"] == 3
    assert itl["count"] == 6          # 2 gaps per 3-token request
    for hist in (ttft, itl):
        assert hist["p50"] <= hist["p99"]
        assert sum(hist["buckets"].values()) == hist["count"]
    status, _ = _get_json(server.port, "/v1/nope")
    assert status == 404


def test_stats_cache_fields_dense(server):
    conn, resp = _post(server.port, {"prompt": [1, 2],
                                     "max_new_tokens": 2, "seed": 0})
    assert _events(resp)[-1][0] == "done"
    conn.close()
    _, stats = _get_json(server.port, "/v1/stats")
    cache = stats["cache"]
    assert cache["allocated"] is True
    assert cache["spec"] == "dense"
    assert cache["builds"] == 1
    assert cache["bytes"]["pool"] > 0


# ----------------------------------------------------------------------
# paged cache over HTTP (DESIGN.md §9)
# ----------------------------------------------------------------------

def test_paged_stats_and_prefix_share_hits(paged_server):
    """Two identical 2-page prompts served back-to-back: the second
    resurrects the first's prompt pages from the prefix LRU — the stats
    endpoint reports the pool, the hit count, and bytes saved by both
    sharing and int8 pages."""
    _, health = _get_json(paged_server.port, "/v1/health")
    assert health["kv"] == "paged:8:int8"

    prompt = list(range(1, 17))          # 16 tokens == 2 full pages
    for seed in (0, 1):
        conn, resp = _post(paged_server.port,
                           {"prompt": prompt, "max_new_tokens": 4,
                            "seed": seed})
        assert _events(resp)[-1][0] == "done"
        conn.close()

    _, stats = _get_json(paged_server.port, "/v1/stats")
    cache = stats["cache"]
    assert cache["spec"] == "paged:8:int8"
    assert cache["page_size"] == 8
    pages = cache["pages"]
    assert pages["total"] == 2 * (MAX_SEQ // 8)   # max_batch * pmax
    assert pages["live"] == 0                     # all retired
    assert pages["free"] + pages["cached"] == pages["total"]
    assert pages["cached"] >= 2                   # prompt pages parked
    prefix = cache["prefix"]
    assert prefix["hits"] >= 2                    # both pages reused
    assert prefix["hit_rate"] > 0
    assert cache["bytes"]["saved_prefix"] > 0
    assert cache["bytes"]["saved_quantized"] > 0
    assert cache["bytes"]["per_page"] < cache["bytes"]["dense_equiv"]
    assert cache["per_request_pages"] == {}       # nothing in flight


@pytest.mark.parametrize("paged_server",
                         [{"max_batch": 2, "queue_capacity": 1,
                           "n_pages": 6}],
                         indirect=True)
def test_paged_pool_exhaustion_backpressure_429(paged_server):
    """A pool sized for ONE worst-case request: the second request parks
    waiting for pages (never a mid-decode failure), the wait line fills,
    and the next arrival is shed with 429 — then everything still
    finishes once pages free up."""
    held = [_post(paged_server.port,
                  {"prompt": [1, 2], "max_new_tokens": 40, "seed": i},
                  timeout=300)
            for i in range(2)]           # each needs 6 pages worst-case
    deadline = time.monotonic() + 30
    status = None
    while time.monotonic() < deadline:
        conn, resp = _post(paged_server.port,
                           {"prompt": [3], "max_new_tokens": 2})
        status = resp.status
        body = resp.read()
        conn.close()
        if status == 429:
            break
        time.sleep(0.02)
    assert status == 429, body
    for conn, resp in held:
        assert _events(resp)[-1][0] == "done"
        conn.close()
    _, stats = _get_json(paged_server.port, "/v1/stats")
    assert stats["queue"]["rejected"] >= 1
    assert stats["requests"]["completed"] >= 2
    assert stats["cache"]["pages"]["live"] == 0


@pytest.mark.parametrize("paged_server", [{"cache_idle": 0.3}],
                         indirect=True)
def test_cache_released_when_idle(paged_server):
    """A long-lived loop must not pin peak-batch cache memory: after the
    idle grace the pool (and its prefix LRU) is freed, and the next
    request lazily rebuilds it."""
    conn, resp = _post(paged_server.port, {"prompt": [1, 2, 3],
                                           "max_new_tokens": 2, "seed": 0})
    assert _events(resp)[-1][0] == "done"
    conn.close()
    deadline = time.monotonic() + 20
    cache = None
    while time.monotonic() < deadline:
        _, stats = _get_json(paged_server.port, "/v1/stats")
        cache = stats["cache"]
        if not cache["allocated"]:
            break
        time.sleep(0.05)
    assert cache["allocated"] is False
    assert cache["pages"]["live"] == 0 and cache["pages"]["cached"] == 0

    conn, resp = _post(paged_server.port, {"prompt": [4, 5],
                                           "max_new_tokens": 2, "seed": 1})
    assert _events(resp)[-1][0] == "done"
    conn.close()
    _, stats = _get_json(paged_server.port, "/v1/stats")
    assert stats["cache"]["builds"] == 2


def test_drain_on_shutdown(engine):
    srv = ServingServer(engine, max_batch=2, prompt_budget=16,
                        queue_capacity=4,
                        scfg=sampling.SamplingConfig(temperature=0.0))
    srv.start()
    conn, resp = _post(srv.port, {"prompt": [1, 2], "max_new_tokens": 6,
                                  "seed": 0}, timeout=120)
    assert resp.status == 200
    t = threading.Thread(target=srv.shutdown,
                         kwargs={"drain": True, "timeout": 60})
    t.start()
    # draining: the in-flight request still completes...
    events = _events(resp)
    conn.close()
    assert events[-1][0] == "done"
    t.join(timeout=60)
    assert not t.is_alive()
