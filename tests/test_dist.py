"""Distributed runtime subsystem (DESIGN.md §11): MeshPlan topology,
per-rank artifact loading, and the decomposed compute-overlapped
collective epilogue (``:overlap``).

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (XLA locks the
host device count at first backend use, so the parent process can't
flip it per-test).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CollectiveSpec
from repro.core.policy import ExecutionPolicy
from repro.dist import MeshPlan

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ---------------------------------------------------------------------------
# MeshPlan (no devices needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("short", ["dp1xtp1", "dp2xtp4", "dp4xtp2xep2"])
def test_mesh_plan_shorthand_round_trips(short):
    plan = MeshPlan.parse(short)
    assert plan.shorthand() == short
    assert MeshPlan.parse(plan.shorthand()) == plan
    # parse is idempotent on plans, and None is the single-device default
    assert MeshPlan.parse(plan) is plan
    assert MeshPlan.parse(None) == MeshPlan(dp=1, tp=1)


def test_mesh_plan_parse_is_order_insensitive_print_is_canonical():
    assert MeshPlan.parse("tp4xdp2") == MeshPlan(dp=2, tp=4)
    assert MeshPlan.parse("tp4xdp2").shorthand() == "dp2xtp4"
    assert MeshPlan.parse("ep2xtp2xdp4") == MeshPlan(dp=4, tp=2, ep=2)


@pytest.mark.parametrize("bad,match", [
    ("dp2xdp4", "repeats"),
    ("dp2", "both dp and tp"),
    ("tp0xdp2", "positive int"),
    ("banana", "unknown mesh spec"),
    ("dp2xtp4xep3", "must divide"),
])
def test_mesh_plan_rejects_malformed_specs(bad, match):
    with pytest.raises(ValueError, match=match):
        MeshPlan.parse(bad)


def test_mesh_plan_geometry_and_policy_field():
    plan = MeshPlan(dp=2, tp=4)
    assert plan.size == 8
    pol = ExecutionPolicy(mesh="dp2xtp4")
    assert pol.mesh == plan
    hash(pol)  # stays jit-static-safe with the new field
    assert ExecutionPolicy().mesh == MeshPlan()
    with pytest.raises(ValueError, match="positive int"):
        MeshPlan(dp=0, tp=2)


def test_single_device_mesh_local_ranks():
    from repro.dist import local_model_ranks

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    assert local_model_ranks(mesh) == (0,)
    assert MeshPlan(dp=1, tp=1).local_model_ranks(mesh) == (0,)


# ---------------------------------------------------------------------------
# :overlap spec flag (no devices needed)
# ---------------------------------------------------------------------------

def test_overlap_flag_parse_round_trips():
    spec = CollectiveSpec.parse("quant-int8:32:overlap")
    assert spec.overlap and not spec.fused
    assert spec.shorthand() == "quant-int8:32:overlap"
    # both flag orders parse; canonical print is :fused then :overlap
    for s in ("quant-int4:32:fused:overlap", "quant-int4:32:overlap:fused"):
        spec = CollectiveSpec.parse(s)
        assert spec.fused and spec.overlap
        assert spec.shorthand() == "quant-int4:32:fused:overlap"
    assert CollectiveSpec.parse(spec.shorthand()) == spec


def test_overlap_flag_rejected_on_non_quant_and_duplicates():
    with pytest.raises(ValueError, match="only applies to quant"):
        CollectiveSpec(name="psum", overlap=True)
    with pytest.raises(ValueError, match="repeat"):
        CollectiveSpec.parse("quant-int8:32:overlap:overlap")


def test_wire_support_reasons():
    """``wire_support`` returns the shape-derived reason ``:fused``
    fallback warnings key on."""
    from repro.core import reorder
    from repro.kernels import dispatch as kdispatch

    r = jax.random.split(jax.random.PRNGKey(0), 3)
    pp = reorder.plan_pair(
        jax.random.normal(r[0], (32, 64)) * 0.1,
        jax.random.normal(r[1], (64, 32)) * 0.1,
        scheme="tp-aware", group_size_up=32, group_size_down=32, rng=r[2])
    q8 = CollectiveSpec.parse("quant-int8:32")
    ok, why = kdispatch.wire_support(pp.down, q8, tp=2)
    assert ok and why == ""
    ok, why = kdispatch.wire_support(pp.down, q8, tp=1)
    assert not ok and "tp=1" in why
    ok, why = kdispatch.wire_support(pp.down, CollectiveSpec(), tp=2)
    assert not ok and "no wire payload" in why


def test_unfusable_warning_dedupes_on_site_and_reason():
    """Satellite regression: the ':fused' fallback warning fires once per
    (site path, reason) — scan re-traces of the same site stay silent,
    but a different reason (or site) still surfaces."""
    import warnings

    from repro.core import reorder, schemes

    r = jax.random.split(jax.random.PRNGKey(1), 3)
    pp = reorder.plan_pair(
        jax.random.normal(r[0], (32, 64)) * 0.1,
        jax.random.normal(r[1], (64, 32)) * 0.1,
        scheme="tp-aware", group_size_up=32, group_size_down=32, rng=r[2])
    schemes._UNFUSABLE_WARNED.clear()
    with pytest.warns(UserWarning) as rec:
        schemes._warn_unfusable("layers.mlp", pp, "tp=1 (no ring to feed)")
    assert len(rec) == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # a repeat would raise
        schemes._warn_unfusable("layers.mlp", pp, "tp=1 (no ring to feed)")
    with pytest.warns(UserWarning):      # same site, new reason
        schemes._warn_unfusable("layers.mlp", pp, "K=64 untileable")
    with pytest.warns(UserWarning):      # new site, old reason
        schemes._warn_unfusable("other.mlp", pp, "tp=1 (no ring to feed)")
    schemes._UNFUSABLE_WARNED.clear()


# ---------------------------------------------------------------------------
# roofline async-window verifier (no devices needed)
# ---------------------------------------------------------------------------

_SCHEDULED_HLO = """\
HloModule m, is_scheduled=true

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8] parameter(0)
  %cp = f32[8,8] collective-permute(%p), source_target_pairs={{0,1},{1,0}}
  %d = f32[8,8] dot(%p, %p), lhs_contracting_dims={1}
  %use = f32[8,8] add(%cp, %d)
  ROOT %r = f32[8,8] add(%use, %d)
}
"""

_SYNC_HLO = _SCHEDULED_HLO.replace(
    "  %cp = f32[8,8] collective-permute(%p), "
    "source_target_pairs={{0,1},{1,0}}\n"
    "  %d = f32[8,8] dot(%p, %p), lhs_contracting_dims={1}\n",
    "  %d = f32[8,8] dot(%p, %p), lhs_contracting_dims={1}\n"
    "  %cp = f32[8,8] collective-permute(%p), "
    "source_target_pairs={{0,1},{1,0}}\n")


def test_parse_overlap_windows_sees_spanned_gemm():
    from repro.launch import roofline

    rep = roofline.parse_overlap_windows(_SCHEDULED_HLO)
    assert rep["collectives"] == 1
    assert rep["spanning"] == 1
    (w,) = rep["windows"]
    assert w["opcode"] == "collective-permute"
    assert w["gemms"] == 1 and w["window_len"] == 1

    rep = roofline.parse_overlap_windows(_SYNC_HLO)
    assert rep["collectives"] == 1 and rep["spanning"] == 0


# ---------------------------------------------------------------------------
# per-rank loader (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

def test_per_rank_loader_shards_match_rank_files_bit_exact():
    """``load_for_mesh`` on a dp4xtp2 mesh: every addressable device
    shard of every split leaf is byte-identical to that model-rank's
    ``rank_NN.npz`` contents, the byte ledger accounts exactly for the
    files read, and a forward through the per-rank params matches the
    host-reassembled ``DeploymentArtifact.load`` path bit-for-bit."""
    out = _run("""
        import os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.core.policy import ExecutionPolicy
        from repro.dist import MeshPlan
        from repro.models.common import ParallelContext
        from repro.models.registry import build_model
        from repro.plan import DeploymentArtifact, compiler
        from repro.train import checkpoint

        cfg = get_smoke_config("qwen3-4b").with_quant(
            mode="mlp", scheme="tp-aware", backend="jnp",
            collective="quant-int8:32")
        policy = ExecutionPolicy.from_config(cfg).with_(
            mesh=MeshPlan(dp=1, tp=2))
        art = compiler.prepare(cfg, tp=2, seed=0, policy=policy,
                               extra_manifest={"smoke": True})
        d = tempfile.mkdtemp()
        art.save(d)
        assert art.manifest["policy"]["mesh"] == "dp1xtp2"

        mesh = MeshPlan.parse("dp4xtp2").build_mesh()
        art2 = DeploymentArtifact.load_for_mesh(d, mesh)
        st = art2.load_stats
        assert st.ranks == (0, 1)          # single process owns all ranks
        assert st.file_bytes_loaded == st.file_bytes_total > 0
        assert not art2.rank_params        # no host-side rank pytrees

        flats = {r: checkpoint.flatten_keys(checkpoint.load(
                     os.path.join(d, f"rank_{r:02d}.npz")))
                 for r in (0, 1)}
        coord = {dev.id: int(idx[-1]) for idx, dev
                 in np.ndenumerate(np.asarray(mesh.devices, dtype=object))}
        gf = checkpoint.flatten_keys(art2.params())
        shard_dims = art2.manifest["leaf_shards"]
        checked = 0
        for key, arr in gf.items():
            dim = shard_dims.get(key)
            for sh in arr.addressable_shards:
                j = coord[sh.device.id]
                want = flats[j][key] if dim is not None else flats[0][key]
                np.testing.assert_array_equal(np.asarray(sh.data),
                                              np.asarray(want))
                checked += 1
        assert checked == 8 * len(gf)      # every leaf on every device

        # the ledger counts exactly the leaves of the two files read
        want_bytes = sum(int(np.asarray(v).nbytes)
                         for f in flats.values() for v in f.values())
        assert st.bytes_loaded == want_bytes

        # forward bit-identity: per-rank assembled vs host-reassembled
        art3 = DeploymentArtifact.load(d)
        model = build_model(cfg)
        ctx = ParallelContext(mesh=mesh, batch_axes=("data",),
                              policy=art2.policy())
        tok = (np.arange(8, dtype=np.int32).reshape(4, 2)
               % cfg.vocab_size)
        f = jax.jit(lambda pr, t: model.forward(pr, {"tokens": t}, ctx))
        outg = np.asarray(f(art2.params(), tok))
        outh = np.asarray(f(art3.params(), tok))
        assert (outg == outh).all()
        print("LOADER_OK")
    """)
    assert "LOADER_OK" in out


def test_mesh_shell_artifact_guards():
    """A manifest-only artifact (mesh mode) refuses the host-global
    accessors instead of silently serving nothing."""
    from repro.plan import DeploymentArtifact

    shell = DeploymentArtifact(manifest={"tp": 2, "leaf_shards": {}})
    with pytest.raises(ValueError, match="no rank pytrees"):
        shell.params()
    with pytest.raises(ValueError, match="cannot re-save"):
        shell.save("/tmp/should-not-exist")


# ---------------------------------------------------------------------------
# overlapped epilogue: bit-identity + real spanned windows (subprocess)
# ---------------------------------------------------------------------------

def test_overlap_epilogue_bit_identical_and_spans_gemm_all_tp():
    """The acceptance gate: at tp in {2,4,8}, for quant-int8 and
    quant-int4, plain and ``:fused``, the ``:overlap`` epilogue is
    BIT-identical to the synchronous two-phase ring, and the compiled
    schedule actually issues ring ppermutes whose in-flight windows span
    a dequant-GEMM (spanning==0 for every synchronous variant)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import reorder
        from repro.core.policy import ExecutionPolicy
        from repro.launch import roofline

        r = jax.random.split(jax.random.PRNGKey(0), 3)
        pp = reorder.plan_pair(
            jax.random.normal(r[0], (64, 256)) * 0.1,
            jax.random.normal(r[1], (256, 96)) * 0.1,
            scheme="tp-aware", group_size_up=32, group_size_down=32,
            rng=r[2])
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))

        for tp in (2, 4, 8):
            mesh = jax.make_mesh((8 // tp, tp), ("data", "model"))
            for base in ("quant-int8:32", "quant-int4:32",
                         "quant-int8:32:fused", "quant-int4:32:fused"):
                outs, spans = {}, {}
                for suffix in ("", ":overlap"):
                    pol = ExecutionPolicy(collective=base + suffix)
                    fn = jax.jit(lambda xx, p, pol=pol, mesh=mesh:
                                 p.forward(xx, pol, mesh, activation=None))
                    c = fn.lower(x, pp).compile()
                    outs[suffix] = np.asarray(fn(x, pp))
                    spans[suffix] = roofline.parse_overlap_windows(
                        c.as_text())["spanning"]
                assert (outs[""] == outs[":overlap"]).all(), (tp, base)
                assert spans[":overlap"] >= 1, (tp, base, spans)
                assert spans[""] == 0, (tp, base, spans)
                print(f"tp={tp} {base}: identical, "
                      f"spanning={spans[':overlap']}")
        print("OVERLAP_OK")
    """)
    assert "OVERLAP_OK" in out


def test_tuner_marks_overlap_opt_in():
    """``prepare(autotune=True, tune_overlap=True)`` marks quantized pair
    choices ':overlap' (never attn_vo sites); default stays unmarked."""
    from repro.comm import CollectivePlan
    from repro.configs import get_smoke_config
    from repro.plan import compiler

    cfg = get_smoke_config("qwen3-4b").with_quant(
        mode="mlp", scheme="tp-aware", backend="jnp", collective="psum")
    art = compiler.prepare(cfg, tp=2, seed=0, autotune=True,
                           tune_overlap=True,
                           extra_manifest={"smoke": True})
    plan = art.manifest["collective_plan"]
    quant_entries = [s for _, s in plan["entries"] if s.startswith("quant")]
    assert quant_entries, plan
    assert all(s.endswith(":overlap") for s in quant_entries), plan
    assert plan["default"] == "psum"
    for site in art.manifest["collective_tuner"]:
        if site["chosen"].startswith("quant") and site["kind"] == "pair":
            assert site["overlap"] is True
    pol = art.policy()
    assert isinstance(pol.collective, CollectivePlan)
    art.validate(cfg=cfg, policy=pol, tp=2)
