"""Training substrate: optimizer math, loss descent, checkpoint roundtrip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.common import REPLICATED
from repro.models.registry import build_model
from repro.train import checkpoint, data as data_lib, optimizer as opt
from repro.train import trainstep


def test_adamw_first_step_matches_reference():
    """After one step from zero state, AdamW ~= -lr * sign-ish update."""
    ocfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None,
                           warmup_steps=0, total_steps=10**9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, -0.25])}
    state = opt.init_state(params)
    new_params, new_state = opt.apply_updates(ocfg, params, grads, state)
    # bias-corrected mhat = g, vhat = g^2 -> update = -lr * g/|g| = -lr*sign
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               np.asarray([1.0 - 0.1, -2.0 + 0.1]),
                               rtol=1e-4)
    assert int(new_state["step"]) == 1


def test_grad_clipping():
    ocfg = opt.AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 100.0)}
    assert float(opt.global_norm(g)) > 1.0
    # clipping happens inside apply_updates; check the step magnitude
    params = {"w": jnp.zeros(4)}
    state = opt.init_state(params)
    _, st2 = opt.apply_updates(ocfg, params, g, state)
    # m after clip: (1-b1) * g_clipped, |g_clipped| = 1
    m = np.asarray(st2["m"]["w"])
    np.testing.assert_allclose(np.linalg.norm(m / 0.1), 1.0, rtol=1e-4)


def test_cosine_schedule_shape():
    ocfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                           min_lr_frac=0.1)
    lrs = [float(opt.cosine_lr(ocfg, jnp.int32(s))) for s in
           (0, 5, 10, 60, 110, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert 0.1 < lrs[3] < 1.0                # decaying
    assert abs(lrs[4] - 0.1) < 1e-6          # floor
    assert abs(lrs[5] - 0.1) < 1e-6          # clamped past end


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8))
    labels = jnp.asarray([[1, 2, 3, 4], [0, 7, -1, 2]])
    got = trainstep.cross_entropy(logits, labels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    want, n = 0.0, 0
    for b in range(2):
        for t in range(4):
            if int(labels[b, t]) != -1:
                want -= float(logp[b, t, int(labels[b, t])])
                n += 1
    np.testing.assert_allclose(float(got), want / n, rtol=1e-5)


def test_loss_decreases_on_synthetic_data():
    cfg = get_smoke_config("qwen3-4b").with_quant(mode="none")
    model = build_model(cfg)
    state = trainstep.init_train_state(model, jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3, total_steps=30, warmup_steps=2)
    step = jax.jit(trainstep.make_train_step(model, REPLICATED, ocfg),
                   donate_argnums=0)
    dcfg = data_lib.DataConfig(seq_len=32, global_batch=4,
                               vocab_size=cfg.vocab_size)
    it = data_lib.batches(dcfg)
    losses = []
    for _ in range(10):
        state, metrics = step(state, next(it))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("granite-3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))    # includes PlannedPairs
    path = checkpoint.save(str(tmp_path / "ck"), params, step=7)
    assert path.endswith("_step00000007.npz")
    restored = checkpoint.restore(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.latest(str(tmp_path), "ck") == path


def test_data_pipeline_shapes_and_determinism():
    dcfg = data_lib.DataConfig(seq_len=16, global_batch=4, vocab_size=97,
                               seed=3)
    a = next(data_lib.batches(dcfg))
    b = next(data_lib.batches(dcfg))
    assert a["tokens"].shape == (4, 16)
    assert a["labels"].shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert int(a["tokens"].max()) < 97


def test_file_backed_data(tmp_path):
    toks = np.arange(1000, dtype=np.uint16) % 50
    f = tmp_path / "corpus.bin"
    toks.tofile(str(f))
    dcfg = data_lib.DataConfig(seq_len=8, global_batch=2, vocab_size=50,
                               path=str(f))
    batch = next(data_lib.batches(dcfg))
    t = np.asarray(batch["tokens"])
    l = np.asarray(batch["labels"])
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])   # shifted by one
