"""Hypothesis property test for the attention V->O fold exactness.

Kept apart from ``test_attention_fold.py`` so the deterministic suite
runs without the optional ``hypothesis`` dependency.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core import attention_fold as af

from test_attention_fold import _setup, _unfolded_reference


@given(kv=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2, 4]),
       hdp=st.sampled_from([16, 32]))
@settings(max_examples=8, deadline=None)
def test_fold_exact_property(kv, g, hdp):
    h = kv * g
    pp, x, aw, _ = _setup(kv * 100 + g * 10 + hdp, h, kv, hdp, 48, b=1, s=4)
    y_fold = af.attention_vo_reference(x, None, aw, pp, n_heads=h,
                                       n_kv_heads=kv, head_dim=hdp)
    y_ref = _unfolded_reference(pp, x, aw, h, kv, hdp)
    scale = float(jnp.abs(y_ref).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_ref),
                               atol=1e-4 * scale)
