"""repro.analysis: every rule fires on its seeded violation, and the
clean tree / clean artifact produce zero findings.

The seeded fixtures are the contract that the linters CAN detect what
they claim (a linter that never fires passes every clean-tree check);
the clean runs are the contract that the current tree actually holds
the invariants.
"""

import json
import os
import shutil
import subprocess
import sys

import jax
import pytest

from repro.analysis import ast_lint, hlo_lint, manifest_lint
from repro.analysis.findings import (Finding, RULES, has_errors, summarize)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# findings plumbing
# ---------------------------------------------------------------------------

def test_unregistered_rule_refused():
    with pytest.raises(ValueError, match="unregistered rule"):
        Finding("ZZ999", "nope")


def test_severity_defaults_from_catalog():
    f = Finding("HL004", "copy")
    assert f.severity == "warn"
    assert not has_errors([f])
    assert has_errors([f, Finding("AS001", "raw")])


def test_summary_shape():
    s = summarize([Finding("AS004", "m")])
    assert s["counts"]["error"] == 1
    assert s["rules_checked"] == sorted(RULES)
    assert s["findings"][0]["layer"] == "ast"


# ---------------------------------------------------------------------------
# AST rules (seeded violations + clean tree)
# ---------------------------------------------------------------------------

RAW_COLLECTIVE_SRC = """\
import jax

def leak(y):
    return jax.lax.psum(y, "model")
"""


def test_as001_raw_collective_fires():
    fs = ast_lint.lint_source(RAW_COLLECTIVE_SRC, "repro/models/foo.py")
    assert _rules(fs) == {"AS001"}
    assert "foo.py:4" in fs[0].location


def test_as001_allowed_inside_comm_and_dist():
    for rel in ("repro/comm/foo.py", "repro/dist/foo.py"):
        assert ast_lint.lint_source(RAW_COLLECTIVE_SRC, rel) == []


def test_as002_kernel_bypass_fires():
    src = ("from repro.kernels import ops\n"
           "def f(x, ql, p):\n"
           "    return ops.pallas_dequant_matmul_ordered(x, ql, p)\n")
    fs = ast_lint.lint_source(src, "repro/models/foo.py")
    assert _rules(fs) == {"AS002"}
    # the dispatch module itself (imported as kdispatch) is the allowed
    # caller, as is anything under kernels/
    assert ast_lint.lint_source(src, "repro/kernels/foo.py") == []
    ok = "import d as kdispatch\nr = kdispatch.dequant_matmul(1)\n"
    assert ast_lint.lint_source(ok, "repro/models/foo.py") == []


def test_as003_unfrozen_spec_dataclass_fires():
    src = ("import dataclasses\n"
           "@dataclasses.dataclass\n"
           "class LooseSpec:\n"
           "    name: str = 'x'\n")
    fs = ast_lint.lint_source(src, "repro/comm/spec.py")
    assert _rules(fs) == {"AS003"}
    # frozen=True passes; non-spec modules are not checked
    frozen = src.replace("@dataclasses.dataclass",
                         "@dataclasses.dataclass(frozen=True)")
    assert ast_lint.lint_source(frozen, "repro/comm/spec.py") == []
    assert ast_lint.lint_source(src, "repro/models/foo.py") == []


def test_as004_mutable_default_fires():
    fs = ast_lint.lint_source("def f(x, acc=[]):\n    return acc\n",
                              "repro/core/foo.py")
    assert _rules(fs) == {"AS004"}
    fs = ast_lint.lint_source("def f(*, acc={}):\n    return acc\n",
                              "repro/core/foo.py")
    assert _rules(fs) == {"AS004"}


def test_clean_tree_has_zero_ast_findings():
    assert ast_lint.run() == []


# ---------------------------------------------------------------------------
# HLO rules (seeded dumps + compiled sweep)
# ---------------------------------------------------------------------------

HLO_WIDEN = """\
HloModule w

ENTRY %main (p0: bf16[8,16]) -> f32[8,16] {
  %p0 = bf16[8,16]{1,0} parameter(0)
  %c = f32[8,16]{1,0} convert(bf16[8,16]{1,0} %p0)
  ROOT %r = f32[8,16]{1,0} add(f32[8,16]{1,0} %c, f32[8,16]{1,0} %c)
}
"""

HLO_DONATED = """\
HloModule m, input_output_alias={ {0}: (0, {}, MAY_ALIAS) }

ENTRY %e (p: f32[8]) -> f32[8] {
  %p.1 = f32[8]{0} parameter(0)
  ROOT %copy.3 = f32[8]{0} copy(f32[8]{0} %p.1)
}
"""


def test_hl002_widening_convert_fires():
    fs = hlo_lint.lint_hlo_text(HLO_WIDEN)
    assert _rules(fs) == {"HL002"}
    # a matched round trip (intended wire compression) is clean
    rt = HLO_WIDEN.replace(
        "ROOT %r = f32[8,16]{1,0} add(f32[8,16]{1,0} %c, "
        "f32[8,16]{1,0} %c)",
        "%n = bf16[8,16]{1,0} convert(f32[8,16]{1,0} %c)\n"
        "  ROOT %r = bf16[8,16]{1,0} copy(bf16[8,16]{1,0} %n)")
    assert hlo_lint.lint_hlo_text(rt) == []


def test_hl002_root_dtype_fires():
    fs = hlo_lint.lint_hlo_text(HLO_WIDEN, expect_root_dtype="bf16")
    assert [f.rule for f in fs] == ["HL002", "HL002"]
    assert "root dtype" in fs[-1].message


def test_hl001_byte_mismatch_fires():
    # no collective in the module but the plan predicts wire traffic
    fs = hlo_lint.lint_hlo_text("ENTRY %x () -> f32[2] {\n}\n",
                                expected_bytes={"layers.mlp": 1024.0})
    assert _rules(fs) == {"HL001"}
    assert fs[0].detail["analytic"] == 1024.0


def test_hl003_missing_overlap_fires():
    fs = hlo_lint.lint_hlo_text(
        "ENTRY %x () -> f32[2] {\n}\n",
        expect_overlap_kinds=("collective-permute",))
    assert _rules(fs) == {"HL003"}


def test_hl004_donated_copy_fires():
    fs = hlo_lint.lint_hlo_text(HLO_DONATED)
    assert _rules(fs) == {"HL004"}
    assert fs[0].severity == "warn"
    assert fs[0].detail["param"] == "p.1"
    # same program without the alias: a copy of a plain param is fine
    assert hlo_lint.lint_hlo_text(
        HLO_DONATED.replace(", input_output_alias={ {0}: (0, {}, "
                            "MAY_ALIAS) }", "")) == []


def test_site_sweep_measured_equals_analytic():
    """The acceptance sweep: at tp {2,4,8} the measured HLO collective
    bytes equal the analytic ``bytes_on_wire`` (rel < 1e-6) for psum /
    psum_scatter / quant-int8 / quant-int4, overlap windows span a GEMM
    for the ':overlap' variants, and no dtype rule fires.  Runs in a
    subprocess: the host device count must be set before jax imports."""
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "from repro.analysis import hlo_lint\n"
        "fs = hlo_lint.run_site_sweep(tps=(2, 4, 8),"
        " specs=hlo_lint.SWEEP_SPECS)\n"
        "fs += hlo_lint.run_site_sweep(tps=(2,),"
        " specs=hlo_lint.SWEEP_OVERLAP_SPECS)\n"
        "assert not fs, [str(f) for f in fs]\n"
        "print('SWEEP-CLEAN')\n")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SWEEP-CLEAN" in r.stdout


# ---------------------------------------------------------------------------
# contract rules (seeded via monkeypatch; clean run at tp=1)
# ---------------------------------------------------------------------------

def test_ct002_nonzero_bytes_fires(monkeypatch):
    from repro.analysis import contracts
    from repro.comm.spec import CollectiveSpec

    monkeypatch.setattr(CollectiveSpec, "bytes_on_wire",
                        lambda self, shape, tp: 42.0)
    fs = contracts.lint_collectives(specs=["psum"], tps=(1,))
    assert "CT002" in _rules(fs)
    assert any("42.0" in f.message for f in fs)


def test_ct002_identity_violation_fires(monkeypatch):
    import jax.numpy as jnp

    from repro.analysis import contracts
    from repro.comm import dispatch as comm_dispatch

    orig = comm_dispatch.apply
    monkeypatch.setattr(
        comm_dispatch, "apply",
        lambda y, axis, spec, policy=None:
            orig(y, axis, spec, policy).astype(jnp.bfloat16))
    fs = contracts.lint_collectives(specs=["psum"], tps=(1,))
    # the float32 stream comes back bfloat16 -> tp=1 is not the identity
    assert "CT002" in _rules(fs)


def test_ct001_dtype_leak_fires_at_tp2():
    """CT001 needs a real multi-device trace; seed the leak in a
    2-device subprocess by wrapping comm.dispatch.apply in a cast."""
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=2'\n"
        "import jax.numpy as jnp\n"
        "from repro.comm import dispatch as comm_dispatch\n"
        "orig = comm_dispatch.apply\n"
        "comm_dispatch.apply = (lambda y, axis, spec, policy=None:\n"
        "    orig(y, axis, spec, policy).astype(jnp.bfloat16))\n"
        "from repro.analysis import contracts\n"
        "fs = contracts.lint_collectives(specs=['psum'], tps=(2,))\n"
        "assert any(f.rule == 'CT001' for f in fs), [str(f) for f in fs]\n"
        "print('CT001-FIRES')\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "CT001-FIRES" in r.stdout


def test_ct003_wrong_cache_geometry_fires(monkeypatch):
    import jax.numpy as jnp

    from repro.analysis import contracts
    from repro.configs import get_smoke_config
    from repro.models import transformer

    cfg = get_smoke_config("qwen3-4b")
    monkeypatch.setattr(contracts, "_family_smoke_cfgs",
                        lambda: {"dense": cfg})
    monkeypatch.setattr(
        transformer, "init_paged_cache",
        lambda cfg, b, n, p, bits=None, dtype=jnp.bfloat16:
            {"k": jnp.zeros((1, 1, n, p, 3, 5), dtype)})
    fs = contracts.lint_families()
    assert "CT003" in _rules(fs)


def test_ct004_wrong_logits_dtype_fires(monkeypatch):
    import jax.numpy as jnp

    from repro.analysis import contracts
    from repro.configs import get_smoke_config
    from repro.models import transformer

    cfg = get_smoke_config("qwen3-4b")
    monkeypatch.setattr(contracts, "_family_smoke_cfgs",
                        lambda: {"dense": cfg})
    orig = transformer.forward
    monkeypatch.setattr(
        transformer, "forward",
        lambda *a, **k: orig(*a, **k).astype(jnp.bfloat16))
    fs = contracts.lint_families()
    assert "CT004" in _rules(fs)


def test_contracts_clean_at_tp1():
    from repro.analysis import contracts

    assert contracts.lint_collectives(tps=(1,)) == []


# ---------------------------------------------------------------------------
# manifest rules (seeded manifests + clean artifact)
# ---------------------------------------------------------------------------

def _plan_manifest(entries, default="psum", pairs=("layers.mlp",),
                   tuner=None):
    short = "per-layer:" + ",".join(
        f"{p}={s}" for p, s in entries) + f",*={default}"
    man = {
        "format_version": 1,
        "tp": 2,
        "policy": {"collective": short},
        "pairs": [{"path": p, "stacked": [2]} for p in pairs],
        "collective_plan": {"entries": [list(e) for e in entries],
                            "default": default},
    }
    if tuner is not None:
        man["collective_tuner"] = tuner
    return man


def test_mf001_unreachable_glob_fires():
    man = _plan_manifest([("bogus.path", "quant-int8:128"),
                          ("layers.mlp", "psum")])
    fs = manifest_lint.lint_manifest_dict(man)
    assert _rules(fs) == {"MF001"}


def test_mf002_shadowed_glob_fires():
    man = _plan_manifest([("*mlp", "quant-int8:128"),
                          ("layers.mlp", "psum")])
    fs = manifest_lint.lint_manifest_dict(man)
    assert _rules(fs) == {"MF002"}


def test_mf003_unprovenanced_fused_mark_fires():
    man = _plan_manifest([("layers.mlp", "quant-int8:128:fused")])
    fs = manifest_lint.lint_manifest_dict(man)
    assert _rules(fs) == {"MF003"}
    assert "no tuner record" in fs[0].message


def test_mf003_contradicted_eligibility_fires():
    tuner = [{"path": "layers.mlp", "kind": "pair", "tp": 2,
              "status": "tuned", "chosen": "quant-int8:128:fused",
              "fused": True, "overlap": False,
              "eligibility": {"fusable": False,
                              "reason": "K=24 is not a multiple of 256"}}]
    man = _plan_manifest([("layers.mlp", "quant-int8:128:fused")],
                         tuner=tuner)
    fs = manifest_lint.lint_manifest_dict(man)
    assert _rules(fs) == {"MF003"}
    assert "not a multiple" in fs[0].message


def test_mf003_recorded_eligibility_passes():
    tuner = [{"path": "layers.mlp", "kind": "pair", "tp": 2,
              "status": "tuned", "chosen": "quant-int8:128:fused",
              "fused": True, "overlap": False,
              "eligibility": {"fusable": True, "reason": ""}}]
    man = _plan_manifest([("layers.mlp", "quant-int8:128:fused")],
                         tuner=tuner)
    assert manifest_lint.lint_manifest_dict(man) == []


def test_mf006_shorthand_echo_mismatch_fires():
    man = _plan_manifest([("layers.mlp", "psum")])
    man["collective_plan"]["entries"] = [["layers.mlp", "cast:bfloat16"]]
    fs = manifest_lint.lint_manifest_dict(man)
    assert "MF006" in _rules(fs)


def test_mf006_unparseable_shorthand_fires():
    man = _plan_manifest([("layers.mlp", "psum")])
    man["policy"]["collective"] = "per-layer:*=psum,layers.mlp=cast"
    fs = manifest_lint.lint_manifest_dict(man)
    assert "MF006" in _rules(fs)


def test_mf005_unconsumed_fold_fires():
    fs = manifest_lint._lint_fold_coverage(
        {"arch_id": "qwen3-4b"},
        {"attn_plans": {"bogus.attn": None}}, location="t")
    assert _rules(fs) == {"MF005"}
    assert fs[0].severity == "error"


def test_mf005_waived_fold_is_info():
    fs = manifest_lint._lint_fold_coverage(
        {"arch_id": "whisper-large-v3"},
        {"attn_plans": {"dec_layers.attn": None,
                        "dec_layers.xattn": None,
                        "enc_layers.attn": None}}, location="t")
    # consumed path silent, the two waived paths reported as info
    assert [f.rule for f in fs] == ["MF005", "MF005"]
    assert {f.severity for f in fs} == {"info"}
    assert not has_errors(fs)


def test_bn001_bad_snapshot_fires(tmp_path):
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps({"bench": "x", "git_sha": "abc"}))
    fs = manifest_lint.lint_bench_snapshots(root=str(tmp_path))
    assert _rules(fs) == {"BN001"}
    good = {"bench": "y", "git_sha": "abc", "created": "t",
            "environment": {"jax": "0", "backend": "cpu",
                            "device_count": 1},
            "config": {}, "metrics": {"m": 1}}
    (tmp_path / "BENCH_y.json").write_text(json.dumps(good))
    fs = manifest_lint.lint_bench_snapshots(
        paths=[str(tmp_path / "BENCH_y.json")])
    assert fs == []
    # bench field must match the filename stem
    good["bench"] = "z"
    (tmp_path / "BENCH_y.json").write_text(json.dumps(good))
    fs = manifest_lint.lint_bench_snapshots(
        paths=[str(tmp_path / "BENCH_y.json")])
    assert _rules(fs) == {"BN001"}


def test_committed_snapshots_are_clean():
    assert manifest_lint.lint_bench_snapshots(root=REPO) == []


# ---------------------------------------------------------------------------
# end-to-end: prepared artifact audits clean; seeded disk violations fire
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    from repro.configs import get_smoke_config
    from repro.plan import compiler

    cfg = get_smoke_config("qwen3-4b")
    out = str(tmp_path_factory.mktemp("art") / "plan")
    art = compiler.prepare(cfg, tp=2, seed=0, autotune=True)
    art.save(out)
    return out


def test_clean_artifact_has_zero_findings(artifact_dir):
    assert manifest_lint.lint_artifact(artifact_dir) == []


def test_mf004_missing_and_stray_rank_files_fire(artifact_dir, tmp_path):
    broken = str(tmp_path / "broken")
    shutil.copytree(artifact_dir, broken)
    os.rename(os.path.join(broken, "rank_01.npz"),
              os.path.join(broken, "rank_05.npz"))
    fs = manifest_lint.lint_artifact(broken)
    msgs = [f.message for f in fs if f.rule == "MF004"]
    assert any("missing rank shard" in m for m in msgs)
    assert any("stray rank shard" in m for m in msgs)


def test_mf003_on_disk_rederivation_fires(tmp_path):
    """A ':fused' mark whose rank-0 shard cannot take the wire epilogue
    — forged provenance says fusable, but ``wire_support`` re-derived
    from the pair on disk (a naive-actorder layout, which has no
    wire-epilogue kernel) refuses."""
    import jax
    import jax.numpy as jnp

    from repro.core import reorder
    from repro.train import checkpoint

    rng = jax.random.PRNGKey(0)
    k1, n1, n2 = 16, 32, 16
    w_up = jax.random.normal(rng, (k1, n1), jnp.float32) * 0.02
    w_down = jax.random.normal(rng, (n1, n2), jnp.float32) * 0.02
    pp = reorder.plan_pair(w_up, w_down, scheme="naive-actorder",
                           group_size_up=8, group_size_down=8, rng=rng)
    art = tmp_path / "plan"
    art.mkdir()
    tree = {"layers": {"mlp": pp}}
    for r in (0, 1):
        checkpoint.save(str(art / f"rank_{r:02d}"), tree)
    forged = "quant-int4:12:fused"
    man = {
        "format_version": 1, "tp": 2, "arch_id": "qwen3-4b",
        "policy": {"collective": f"per-layer:layers.mlp={forged},*=psum"},
        "pairs": [{"path": "layers.mlp", "stacked": []}],
        "leaf_shards": {k: None
                        for k in checkpoint.flatten_keys(tree)},
        "collective_plan": {"entries": [["layers.mlp", forged]],
                            "default": "psum"},
        "collective_tuner": [
            {"path": "layers.mlp", "kind": "pair", "tp": 2,
             "status": "tuned", "chosen": forged, "fused": True,
             "overlap": False,
             "eligibility": {"fusable": True, "reason": ""}}],
    }
    (art / "manifest.json").write_text(json.dumps(man))
    fs = manifest_lint.lint_artifact(str(art))
    assert any(f.rule == "MF003" and "on disk" in f.message
               for f in fs), [str(f) for f in fs]


def test_serve_verify_subcommand(artifact_dir, tmp_path):
    """``serve verify --artifact`` exits 0 on a clean artifact and
    writes the findings JSON."""
    out = str(tmp_path / "findings.json")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "verify",
         "--artifact", artifact_dir, "--json", out],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    with open(out) as f:
        report = json.load(f)
    assert report["counts"]["error"] == 0
    assert report["rules_checked"] == sorted(RULES)


def test_cli_gate_exits_nonzero_on_violation(tmp_path):
    """The CLI is the CI gate: a tree with a seeded raw collective makes
    ``python -m repro.analysis --ast`` exit 1 with the finding JSON."""
    bad_root = tmp_path / "src" / "repro" / "models"
    bad_root.mkdir(parents=True)
    (bad_root / "bad.py").write_text(RAW_COLLECTIVE_SRC)
    code = (
        "import sys\n"
        "from repro.analysis import ast_lint\n"
        f"fs = ast_lint.run(src_root={str(tmp_path / 'src')!r})\n"
        "assert any(f.rule == 'AS001' for f in fs)\n"
        "sys.exit(1 if fs else 0)\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 1, r.stdout + r.stderr
