"""Roofline HLO parser + mesh helpers."""

import jax
import pytest

from repro.launch import roofline


HLO = """
ENTRY %main {
  %x = f32[8,128]{1,0} parameter(0)
  %ag = f32[8,512]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={1}
  %ar = bf16[8,512]{1,0} all-reduce(%y), replica_groups=[4,2]<=[8], to_apply=%add
  %rs = f32[8,64]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={1}
  %cp = f32[4,16]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %aa = f32[2,8]{1,0} all-to-all(%v), replica_groups={{0,1}}
  %aa2 = (s8[2,8]{1,0}, /*index=1*/f16[2,2]{1,0}) all-to-all(%q, %s), replica_groups={{0,1}}
  %gte = s8[2,8]{1,0} get-tuple-element((s8[2,8]{1,0}, f16[2,2]{1,0}) %aa2), index=0
  %ard = bf16[8,512]{1,0} all-reduce-done(%ar)
  %ags = (f32[8,64]{1,0}, f32[8,64]{1,0}) all-gather-start(%p), replica_groups={{0,1}}
}
"""


def test_parse_collective_bytes():
    out = roofline.parse_collective_bytes(HLO, chips=8)
    ag = 8 * 512 * 4 * 3 / 4                    # (g-1)/g of result
    ar = 8 * 512 * 2 * 2 * 1 / 2                # iota groups [4,2]: g=2
    rs = 8 * 64 * 4 * 7                         # (g-1) x result
    cp = 4 * 16 * 4
    aa = 2 * 8 * 4 * 1 / 2
    aa2 = (2 * 8 * 1 + 2 * 2 * 2) * 1 / 2       # tuple form: sum of entries
    # async tuple-form -start aliases its operand in the tuple, so it is
    # deliberately NOT summed (would double-count); -done never counted
    assert out["all-gather"] == pytest.approx(ag)
    assert out["all-reduce"] == pytest.approx(ar)   # -done not re-counted
    assert out["reduce-scatter"] == pytest.approx(rs)
    assert out["collective-permute"] == pytest.approx(cp)
    assert out["all-to-all"] == pytest.approx(aa + aa2)
    assert out["total_per_device"] == pytest.approx(ag + ar + rs + cp
                                                    + aa + aa2)
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-to-all"] == 2


def test_roofline_terms_and_bottleneck():
    rl = roofline.Roofline(
        arch="a", shape="s", mesh="16x16", chips=256,
        hlo_flops=1e18, hlo_bytes=1e12, collective_bytes=1e15,
        model_flops=5e17)
    assert rl.t_compute == pytest.approx(1e18 / (256 * roofline.PEAK_FLOPS))
    assert rl.t_memory == pytest.approx(1e12 / (256 * roofline.HBM_BW))
    assert rl.t_collective == pytest.approx(1e15 / (256 * roofline.ICI_BW))
    assert rl.bottleneck == "collective"
    assert rl.useful_flops_frac == pytest.approx(0.5)
    j = rl.to_json()
    assert j["bottleneck"] == "collective"


def test_fmt_helpers():
    assert roofline.fmt_seconds(2e-6) == "2.0us"
    assert roofline.fmt_seconds(0.5) == "500.00ms"
    assert roofline.fmt_bytes(2048) == "2.0KB"


def test_probe_plan_units():
    """probe_plan covers every family with 0/1-unit scan configs."""
    from repro.configs import ARCH_IDS, get_config
    from repro.launch.dryrun import probe_plan

    for aid in ARCH_IDS:
        cfg = get_config(aid)
        probes, combine = probe_plan(cfg)
        assert len(probes) >= 2
        # combiner over degenerate equal costs returns that cost
        c0 = {"flops": 1.0, "bytes": 2.0, "coll": 3.0, "counts": {}}
        costs = {k: dict(c0) for k in probes}
        out = combine(costs)
        assert out["flops"] == pytest.approx(1.0)
        assert out["bytes"] == pytest.approx(2.0)
