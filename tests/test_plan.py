"""Plan compiler + DeploymentArtifact: the prepare-once/serve-many path.

Acceptance criteria of the PlanCompiler refactor:

* ``prepare`` (compile_plan -> save) then serve-from-artifact runs WITHOUT
  invoking GPTQ quantization or the layout planner at load time, and its
  logits are bit-identical to the in-memory path for the same
  config/policy/seed,
* checkpoint round-trip of quantized pytrees: ``save`` -> ``load`` ->
  bit-identical ``PlannedPair.forward`` outputs, statics preserved,
* manifest-mismatch rejection: wrong TP degree / policy / config hash.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.policy import ExecutionPolicy
from repro.core.reorder import PlannedPair
from repro.models.common import REPLICATED
from repro.models.registry import build_model
from repro.plan import (DeploymentArtifact, PlanMismatchError, compiler)
from repro.train import checkpoint


def _smoke_cfg(arch="qwen3-4b"):
    return get_smoke_config(arch)


def _prepare(cfg, tp=2, seed=0):
    """The exact pipeline ``launch.serve prepare`` runs."""
    return compiler.prepare(cfg, tp=tp, seed=seed,
                            extra_manifest={"smoke": True})


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# checkpoint round-trip of quantized pytrees
# ---------------------------------------------------------------------------

def test_checkpoint_quantized_roundtrip(tmp_path):
    """save -> template-free load -> bit-identical PlannedPair.forward,
    statics (scheme / group_size / kind) included."""
    from repro.core import reorder

    rng = jax.random.PRNGKey(0)
    r = jax.random.split(rng, 3)
    pp = reorder.plan_pair(
        jax.random.normal(r[0], (64, 128)),
        jax.random.normal(r[1], (128, 64)),
        w_gate=jax.random.normal(r[2], (64, 128)),
        scheme="tp-aware", group_size_up=32, group_size_down=32, rng=rng)
    tree = {"layers": {"mlp": pp}, "scale": jnp.ones((4,))}
    path = checkpoint.save(str(tmp_path / "plan"), tree)
    loaded = checkpoint.load(path)

    lpp = loaded["layers"]["mlp"]
    assert isinstance(lpp, PlannedPair)
    assert lpp.scheme == "tp-aware"
    assert lpp.up.kind == "ordered" and lpp.up.group_size == 32
    assert lpp.up.qweight.dtype == jnp.uint32
    _assert_trees_equal(tree, loaded)

    x = jax.random.normal(r[0], (4, 64))
    np.testing.assert_array_equal(
        np.asarray(pp.forward(x, activation="silu")),
        np.asarray(lpp.forward(x, activation="silu")))


def test_checkpoint_naive_layout_roundtrip(tmp_path):
    """The g_idx (naive) layout keeps its unordered metadata through disk."""
    from repro.core import reorder

    rng = jax.random.PRNGKey(1)
    r = jax.random.split(rng, 2)
    pp = reorder.plan_pair(
        jax.random.normal(r[0], (64, 128)),
        jax.random.normal(r[1], (128, 64)),
        scheme="naive-actorder", group_size_up=32, group_size_down=32,
        rng=rng)
    path = checkpoint.save(str(tmp_path / "naive"), pp)
    lpp = checkpoint.load(path)
    assert lpp.scheme == "naive-actorder"
    assert lpp.up.kind == "naive" and lpp.up.g_idx is not None
    assert lpp.p2 is None
    _assert_trees_equal(pp, lpp)


def test_checkpoint_load_rejects_legacy_files(tmp_path):
    """npz files without the embedded schema demand the template path."""
    p = tmp_path / "legacy.npz"
    np.savez(p, **{"a": np.ones(3)})
    with pytest.raises(ValueError, match="no embedded tree schema"):
        checkpoint.load(str(p))
    # restore() still works on them
    out = checkpoint.restore(str(p), {"a": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(3))


# ---------------------------------------------------------------------------
# compiler stages
# ---------------------------------------------------------------------------

def test_model_init_is_the_compiler():
    """``Model.init`` == raw init + compile_params — one pipeline."""
    cfg = _smoke_cfg()
    key = jax.random.PRNGKey(0)
    m = build_model(cfg)
    planned = m.init(key)
    by_hand = compiler.compile_params(
        cfg, m.init_raw(key),
        rng=jax.random.fold_in(key, compiler.PLAN_RNG_STREAM))
    _assert_trees_equal(planned, by_hand)
    pairs = [x for x in jax.tree_util.tree_leaves(
        planned, is_leaf=lambda x: isinstance(x, PlannedPair))
        if isinstance(x, PlannedPair)]
    assert pairs and all(p.scheme == "tp-aware" for p in pairs)


def test_shard_assemble_identity():
    """stage_shard slices, artifact.params() concatenates: identity."""
    cfg = _smoke_cfg()
    art = _prepare(cfg, tp=2)
    assert len(art.rank_params) == 2
    planned = build_model(cfg).init(jax.random.PRNGKey(0))
    _assert_trees_equal(planned, art.params())
    # sharded leaves really are split (not everything replicated)
    shards = art.manifest["leaf_shards"]
    assert sum(v is not None for v in shards.values()) > 0
    # and a sharded leaf's rank slice is 1/tp of the global extent
    key = next(k for k, v in shards.items() if v is not None)
    flat0 = checkpoint.flatten_keys(art.rank_params[0])
    flatg = checkpoint.flatten_keys(art.params())
    dim = shards[key]
    assert flat0[key].shape[dim] * 2 == flatg[key].shape[dim]


def test_attention_fold_stage():
    """cfg.quant.attn_tp_aware compiles V->O folds into the aux tree."""
    cfg = _smoke_cfg().with_quant(attn_tp_aware=True)
    art = _prepare(cfg, tp=2)
    assert art.aux is not None and art.aux["attn_plans"]
    (path, plans), = art.aux["attn_plans"].items()
    assert "attn" in path
    assert isinstance(plans, PlannedPair) and plans.scheme == "tp-aware"
    # stacked over layers
    assert plans.up.qweight.ndim == 3


# ---------------------------------------------------------------------------
# artifact round-trip: no quantization at load, bit-identical serving
# ---------------------------------------------------------------------------

def _forbid_requantize(monkeypatch):
    """Loading an artifact must never re-run the offline pipeline."""
    from repro.core import quantization, reorder

    def boom(*a, **k):
        raise AssertionError("offline pipeline invoked at load time")

    monkeypatch.setattr(quantization, "quantize", boom)
    monkeypatch.setattr(reorder, "quantize_pair", boom)
    monkeypatch.setattr(reorder, "plan_pair", boom)
    monkeypatch.setattr(compiler, "stage_quantize", boom)


def test_artifact_serves_bit_identical_logits(tmp_path, monkeypatch):
    """The acceptance criterion: prepare -> save -> load -> serve produces
    logits bit-identical to the in-memory path for the same
    config/policy/seed, without invoking GPTQ or plan_pair at load."""
    from repro.runtime.serve import make_engine

    cfg = _smoke_cfg()
    art_dir = str(tmp_path / "artifact")
    _prepare(cfg, tp=1, seed=0).save(art_dir)

    eng_mem = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)

    _forbid_requantize(monkeypatch)
    eng_art = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16,
                          artifact=art_dir)
    _assert_trees_equal(eng_mem.params, eng_art.params)

    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0,
                              cfg.vocab_size)
    y_mem = eng_mem.model.forward(eng_mem.params, {"tokens": toks},
                                  REPLICATED)
    y_art = eng_art.model.forward(eng_art.params, {"tokens": toks},
                                  REPLICATED)
    np.testing.assert_array_equal(np.asarray(y_mem), np.asarray(y_art))

    # and through a decode step (the serving hot path)
    cache = eng_art.init_cache(2)
    l_art, _ = eng_art._decode(eng_art.params, cache, toks[:, 0],
                               jnp.int32(0))
    cache = eng_mem.init_cache(2)
    l_mem, _ = eng_mem._decode(eng_mem.params, cache, toks[:, 0],
                               jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(l_mem), np.asarray(l_art))


def test_artifact_rejects_mismatched_plan(tmp_path):
    cfg = _smoke_cfg()
    art_dir = str(tmp_path / "artifact")
    _prepare(cfg, tp=2).save(art_dir)
    art = DeploymentArtifact.load(art_dir)
    pol = ExecutionPolicy.from_config(cfg)

    art.validate(cfg=cfg, policy=pol, tp=2)          # the matching plan
    with pytest.raises(PlanMismatchError, match="model-axis degree"):
        art.validate(tp=4)
    with pytest.raises(PlanMismatchError, match="policy"):
        art.validate(policy=pol.with_(collective="quant-int8"))
    with pytest.raises(PlanMismatchError, match="scheme|policy"):
        art.validate(policy=pol.with_(scheme="exllama"))
    with pytest.raises(PlanMismatchError, match="config hash"):
        art.validate(cfg=cfg.with_(d_ff=cfg.d_ff * 2))
    with pytest.raises(PlanMismatchError, match="compiled for"):
        art.validate(cfg=dataclasses.replace(cfg, arch_id="other"))


def test_engine_refuses_mismatched_artifact(tmp_path):
    from repro.runtime.serve import make_engine

    cfg = _smoke_cfg()
    art_dir = str(tmp_path / "artifact")
    _prepare(cfg, tp=2).save(art_dir)      # pre-sharded for TP=2
    with pytest.raises(PlanMismatchError, match="model-axis degree"):
        # single-device ctx (tp=1) != the artifact's TP=2 plan
        make_engine(cfg, max_seq=16, artifact=art_dir)


def test_artifact_manifest_contents(tmp_path):
    cfg = _smoke_cfg()
    art_dir = str(tmp_path / "artifact")
    _prepare(cfg, tp=2, seed=5).save(art_dir)
    man = DeploymentArtifact.load(art_dir).manifest
    assert man["arch_id"] == cfg.arch_id
    assert man["tp"] == 2 and man["seed"] == 5
    assert man["policy"]["scheme"] == "tp-aware"
    assert man["policy"]["collective"] == "psum"
    (pair,) = man["pairs"]
    assert pair["scheme"] == "tp-aware"
    assert pair["k1"] == cfg.d_model and pair["n1"] == cfg.d_ff
    assert pair["gate"] is True and pair["stacked"] == [cfg.num_layers]


# ---------------------------------------------------------------------------
# per-layer CollectivePlan through the artifact lifecycle
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_heterogeneous_collective_plan(tmp_path):
    """A per-layer plan with distinct collectives survives
    prepare -> save -> load: the manifest echoes it both as the policy
    shorthand and structurally, ``art.policy()`` reconstructs the same
    frozen plan, and ``validate`` refuses a mismatched plan/policy."""
    from repro.comm import CollectivePlan

    short = "per-layer:*.mlp=quant-int8:64,attn*=cast:float16,*=psum"
    cfg = _smoke_cfg().with_quant(collective=short)
    art_dir = str(tmp_path / "het")
    _prepare(cfg, tp=2).save(art_dir)
    art = DeploymentArtifact.load(art_dir)

    man = art.manifest
    assert man["policy"]["collective"] == short
    assert man["collective_plan"] == {
        "entries": [["*.mlp", "quant-int8:64"],
                    ["attn*", "cast:float16"]],
        "default": "psum",
    }
    shorts = {s for _, s in man["collective_plan"]["entries"]}
    shorts.add(man["collective_plan"]["default"])
    assert len(shorts) >= 2         # genuinely heterogeneous

    pol = art.policy()
    assert isinstance(pol.collective, CollectivePlan)
    assert pol.collective == CollectivePlan.parse(short)
    assert pol.collective.resolve("layers.mlp").block_size == 64
    art.validate(cfg=cfg, policy=pol, tp=2)
    # a bare-spec policy is NOT the per-layer plan it was compiled for
    with pytest.raises(PlanMismatchError, match="policy"):
        art.validate(policy=pol.with_(collective="psum"))
    with pytest.raises(PlanMismatchError, match="policy"):
        art.validate(policy=pol.with_(
            collective="per-layer:*.mlp=quant-int8:128,*=psum"))


def test_autotune_compiles_collective_plan(tmp_path):
    """``prepare(autotune=True)`` scores every full-output strategy per
    pair site and freezes a per-layer ``CollectivePlan`` into the
    artifact: the manifest carries >=2 distinct collectives (the tuned
    site + the psum default), the tuner report names every candidate's
    bytes/error, and the served policy round-trips the plan."""
    from repro.comm import CollectivePlan

    cfg = _smoke_cfg()
    art = compiler.prepare(cfg, tp=2, seed=0, autotune=True,
                           extra_manifest={"smoke": True})
    man = art.manifest
    plan = man["collective_plan"]
    assert plan["default"] == "psum"
    assert [p for p, _ in plan["entries"]] == [m["path"]
                                               for m in man["pairs"]]
    distinct = {s for _, s in plan["entries"]} | {plan["default"]}
    assert len(distinct) >= 2, plan

    (site,) = man["collective_tuner"]
    assert site["path"] == "layers.mlp" and site["status"] == "tuned"
    assert site["chosen"] in dict(
        (s, None) for _, s in plan["entries"]).keys()
    # every candidate was scored with both axes of the trade-off
    assert {"psum"} <= set(site["candidates"])
    for v in site["candidates"].values():
        assert v["rel_err"] >= 0 and v["bytes_per_token"] >= 0
    # the chosen collective actually compresses vs the psum baseline
    cand = site["candidates"]
    assert cand[site["chosen"]]["bytes_per_token"] < \
        cand["psum"]["bytes_per_token"]

    # round-trip through disk, then validate against the tuned policy
    art_dir = str(tmp_path / "tuned")
    art.save(art_dir)
    loaded = DeploymentArtifact.load(art_dir)
    pol = loaded.policy()
    assert isinstance(pol.collective, CollectivePlan)
    loaded.validate(cfg=cfg, policy=pol, tp=2)
    with pytest.raises(PlanMismatchError, match="policy"):
        # the pre-tune (global psum) policy is not the compiled plan
        loaded.validate(policy=ExecutionPolicy.from_config(cfg))


def test_autotune_respects_budget():
    """budget=0 forbids every lossy collective -> psum everywhere;
    a huge budget picks the cheapest wire (int4) for the mlp site."""
    cfg = _smoke_cfg()
    tight = compiler.prepare(cfg, tp=2, seed=0, autotune=True,
                             tune_budget=0.0)
    assert all(s == "psum" for _, s in
               tight.manifest["collective_plan"]["entries"])
    loose = compiler.prepare(cfg, tp=2, seed=0, autotune=True,
                             tune_budget=10.0)
    chosen = dict(loose.manifest["collective_plan"]["entries"])
    assert chosen["layers.mlp"].startswith("quant-int4")
