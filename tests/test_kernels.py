"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as qz
from repro.kernels import dequant_matmul as dk, ops, ref


def _mk(seed, k, n, gs, act_order=True):
    r1, r2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(r1, (k, n))
    return qz.quantize(w, gs, act_order=act_order, rng=r2)


@pytest.mark.parametrize("m,k,n,gs", [
    (8, 128, 128, 32),
    (16, 256, 384, 64),
    (128, 512, 256, 128),
    (1, 256, 128, 64),      # decode-like M=1
    (4, 1024, 128, 128),    # deep K
])
def test_ordered_kernel_sweep(m, k, n, gs):
    res = _mk(m * 3 + k, k, n, gs)
    x = jax.random.normal(jax.random.PRNGKey(9), (m, k))
    ql = res.ordered
    y = dk.dequant_matmul_ordered(x, ql.qweight, ql.scales, ql.zeros,
                                  group_size=gs)
    y_ref = ref.dequant_matmul_ordered(x, ql.qweight, ql.scales, ql.zeros,
                                       group_size=gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n,gs", [
    (8, 128, 128, 32),
    (16, 256, 384, 64),
    (32, 512, 256, 128),
])
def test_gidx_kernel_sweep(m, k, n, gs):
    res = _mk(m * 5 + n, k, n, gs)
    x = jax.random.normal(jax.random.PRNGKey(10), (m, k))
    ql = res.naive
    y = dk.dequant_matmul_gidx(x, ql.qweight, ql.scales, ql.zeros, ql.g_idx)
    y_ref = ref.dequant_matmul_gidx(x, ql.qweight, ql.scales, ql.zeros,
                                    ql.g_idx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("k,n,gs", [(128, 128, 32), (512, 384, 128)])
def test_dequantize_kernel(k, n, gs):
    res = _mk(k + n, k, n, gs)
    ql = res.ordered
    y = dk.dequantize_ordered(ql.qweight, ql.scales, ql.zeros, group_size=gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.dequantize(ql)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ops_wrapper_dtypes_and_padding(dtype):
    """ops.dequant_matmul handles leading batch dims + non-tile N/M."""
    res = _mk(42, 128, 96, 32)   # N=96 not a multiple of 128 -> padded
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 3, 128)).astype(dtype)
    for ql in (res.ordered, res.naive):
        y = ops.dequant_matmul(x, ql, compute_dtype=jnp.float32)
        y_ref = ref.dequant_matmul(x.astype(jnp.float32), ql)
        assert y.shape == (2, 3, 96)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-2, atol=2e-2)


def test_kernel_matches_scheme_forward():
    """backend='pallas' pair forward == backend='jnp' (policy-selected)."""
    from repro.core import reorder
    from repro.core.policy import ExecutionPolicy

    rng = jax.random.PRNGKey(12)
    r = jax.random.split(rng, 3)
    pp = reorder.plan_pair(
        jax.random.normal(r[0], (128, 256)),
        jax.random.normal(r[1], (256, 128)),
        scheme="tp-aware", group_size_up=32, group_size_down=32, rng=rng)
    x = jax.random.normal(r[2], (8, 128))
    y_jnp = pp.forward(x, ExecutionPolicy(backend="jnp"), activation="silu")
    y_pal = pp.forward(x, ExecutionPolicy(backend="pallas"),
                       activation="silu")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_jnp),
                               rtol=1e-4, atol=1e-3)


def test_pick_block_k():
    assert dk.pick_block_k(1024, 128) % 128 == 0
    assert 1024 % dk.pick_block_k(1024, 128) == 0
    assert dk.pick_block_k(608, 76) % 76 == 0


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,d,causal,window,bq,bk", [
    (1, 2, 128, 32, True, None, 64, 64),
    (2, 2, 256, 64, True, None, 128, 128),
    (1, 1, 128, 32, False, None, 64, 64),
    (1, 2, 256, 32, True, 64, 64, 64),
    (1, 2, 128, 32, True, None, 128, 32),   # uneven q/k blocks
])
def test_flash_attention_sweep(b, h, s, d, causal, window, bq, bk):
    from repro.kernels import ops

    r1, r2, r3 = jax.random.split(jax.random.PRNGKey(b * s + d), 3)
    q = jax.random.normal(r1, (b, h, s, d), jnp.float32)
    k = jax.random.normal(r2, (b, h, s, d), jnp.float32)
    v = jax.random.normal(r3, (b, h, s, d), jnp.float32)
    y = ops.flash_attention(q, k, v, causal=causal, window=window,
                            block_q=bq, block_k=bk)
    y_ref = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_dtypes(dtype):
    from repro.kernels import ops

    r = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(rr, (1, 2, 128, 32)).astype(dtype)
               for rr in r)
    y = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    y_ref = ref.flash_attention(q, k, v)
    assert y.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2, atol=2e-2)
