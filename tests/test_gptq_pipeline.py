"""Offline quantize_model pipeline: dense trained params -> deployment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.reorder import PlannedPair
from repro.models.common import REPLICATED
from repro.models.registry import build_model
from repro.quant.gptq import quantize_model


@pytest.mark.parametrize("arch_id", ["granite-3-8b", "qwen3-moe-235b-a22b",
                                     "rwkv6-3b", "recurrentgemma-2b"])
def test_quantize_model_replaces_mlp_pairs(arch_id):
    cfg = get_smoke_config(arch_id).with_quant(mode="none")
    m = build_model(cfg)
    dense = m.init(jax.random.PRNGKey(0))
    q = quantize_model(cfg.with_quant(mode="mlp", scheme="tp-aware"), dense)

    pairs = [x for x in jax.tree.leaves(
        q, is_leaf=lambda x: isinstance(x, PlannedPair))
        if isinstance(x, PlannedPair)]
    assert pairs, "no MLP pair was quantized"
    for pp in pairs:
        assert pp.scheme == "tp-aware"
        assert pp.up.qweight.dtype == jnp.uint32


def test_quantized_model_close_to_dense():
    cfg = get_smoke_config("qwen3-4b").with_quant(mode="none")
    m = build_model(cfg)
    dense = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), 2, 16)
    y_dense = m.forward(dense, batch, REPLICATED).astype(jnp.float32)

    qcfg = cfg.with_quant(mode="mlp", scheme="tp-aware")
    qparams = quantize_model(qcfg, dense)
    y_q = build_model(qcfg).forward(qparams, batch,
                                    REPLICATED).astype(jnp.float32)
    # int4 group quantization of random-init weights: logits stay close
    err = float(jnp.abs(y_dense - y_q).max())
    scale = float(jnp.abs(y_dense).max())
    assert err < 0.25 * scale, err / scale


def test_schemes_agree_through_full_model():
    """The three deployment schemes produce identical model outputs when
    quantizing the same dense params (the paper's exactness claim, checked
    end-to-end through a whole transformer)."""
    cfg = get_smoke_config("granite-3-8b").with_quant(mode="none")
    m = build_model(cfg)
    dense = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), 2, 8)

    outs = {}
    for scheme in ("naive-actorder", "exllama", "tp-aware"):
        qcfg = cfg.with_quant(mode="mlp", scheme=scheme)
        qp = quantize_model(qcfg, dense, rng=jax.random.PRNGKey(7))
        outs[scheme] = np.asarray(
            build_model(qcfg).forward(qp, batch, REPLICATED).astype(
                jnp.float32))
    ref = outs["naive-actorder"]
    scale = np.abs(ref).max()
    for scheme in ("exllama", "tp-aware"):
        np.testing.assert_allclose(outs[scheme], ref, atol=2e-2 * scale,
                                   err_msg=scheme)
