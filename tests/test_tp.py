"""Tensor-parallel invariance: shard_map TP outputs == single-device ref.

XLA locks the host device count at first init, so multi-device tests run
in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_tp_schemes_match_reference():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import reorder
        from repro.core.policy import ExecutionPolicy

        rng = jax.random.PRNGKey(0)
        k1, n1, n2, m = 128, 256, 128, 16
        r = jax.random.split(rng, 4)
        w_up = jax.random.normal(r[0], (k1, n1))
        w_gate = jax.random.normal(r[1], (k1, n1))
        w_down = jax.random.normal(r[2], (n1, n2))
        x = jax.random.normal(r[3], (m, k1))

        for tp, dp in ((2, 4), (4, 2), (8, 1)):
            mesh = jax.make_mesh((dp, tp), ("data", "model"))
            for scheme in reorder.SCHEMES:
                pp = reorder.plan_pair(
                    w_up, w_down, w_gate=w_gate, scheme=scheme,
                    group_size_up=32, group_size_down=32, rng=rng)
                ref = np.asarray(pp.forward(x, activation="silu"))
                with mesh:
                    for coll in ("psum", "psum_scatter"):
                        pol = ExecutionPolicy(scheme=scheme,
                                              collective=coll)
                        y = np.asarray(pp.forward(
                            x, pol, mesh, batch_axes=("data",),
                            activation="silu"))
                        err = np.abs(y - ref).max() / np.abs(ref).max()
                        assert err < 1e-4, (tp, scheme, coll, err)
                        print("OK", tp, scheme, coll)
    """)
    assert out.count("OK") == 18


def test_tp_model_forward_matches_single_device():
    """Full smoke-model forward under a (2, 4) mesh == replicated run."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.registry import build_model
        from repro.models.common import ParallelContext, REPLICATED

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for aid in ("granite-3-8b", "rwkv6-3b"):
            cfg = get_smoke_config(aid)
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            batch = m.make_batch(jax.random.PRNGKey(1), 4, 16)
            y_ref = np.asarray(m.forward(params, batch, REPLICATED))
            ctx = ParallelContext(mesh=mesh, batch_axes=("data",))
            with mesh:
                y_tp = np.asarray(jax.jit(
                    lambda p, b: m.forward(p, b, ctx))(params, batch))
            err = np.abs(y_tp - y_ref).max() / (np.abs(y_ref).max() + 1e-6)
            assert err < 2e-2, (aid, err)   # bf16 activations
            print("OK", aid, err)
    """)


def test_multipod_mesh_constructs():
    _run("""
        import jax
        from repro.launch import mesh as mesh_lib
        # 8 host devices: build a small (2, 2, 2) pod/data/model mesh the
        # same way the production (2, 16, 16) one is built.
        m = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                          devices=jax.devices()[:8])
        assert m.axis_names == ("pod", "data", "model")
        assert mesh_lib.batch_axes_for(m, 8) == ("pod", "data")
        assert mesh_lib.batch_axes_for(m, 1) == ()
        print("OK")
    """)
