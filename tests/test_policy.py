"""ExecutionPolicy plumbing: config round-trip, dispatch resolution, and
context/engine policy threading.

Covers the acceptance criteria of the policy redesign:
* ``ExecutionPolicy.from_config`` works for every arch config and parses
  the ``QuantConfig.collective`` shorthand into a ``CollectiveSpec``,
* ``kernels/dispatch.py`` resolves every seeded (kind, backend) pair and
  errors helpfully on unknown backends,
* the policy is the only spelling — there are no legacy loose kwargs and
  no ``reduce``/``reduce_dtype`` string fields anywhere.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CollectiveSpec
from repro.configs import ARCH_IDS, QuantConfig, get_config
from repro.core import reorder, schemes
from repro.core.policy import (DEFAULT_POLICY, ExecutionPolicy,
                               KernelTiling, resolve_policy)
from repro.kernels import dispatch


def _mk_pair(seed, k1, n1, n2, gs, scheme, gate=True):
    rng = jax.random.PRNGKey(seed)
    r = jax.random.split(rng, 4)
    w_up = jax.random.normal(r[0], (k1, n1))
    w_gate = jax.random.normal(r[1], (k1, n1)) if gate else None
    w_down = jax.random.normal(r[2], (n1, n2))
    pp = reorder.plan_pair(w_up, w_down, w_gate=w_gate, scheme=scheme,
                           group_size_up=gs, group_size_down=gs, rng=rng)
    x = jax.random.normal(r[3], (8, k1))
    return pp, x


# ---------------------------------------------------------------------------
# from_config / auto
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_from_config_every_arch(arch):
    cfg = get_config(arch)
    pol = ExecutionPolicy.from_config(cfg)
    assert pol.scheme == cfg.quant.scheme
    assert pol.backend in dispatch.backends()
    assert pol.collective == CollectiveSpec.parse(cfg.quant.collective)
    # ModelConfig and its QuantConfig describe the same plan
    assert ExecutionPolicy.from_config(cfg.quant) == pol


def test_from_config_explicit_fields():
    qc = QuantConfig(scheme="exllama", backend="pallas",
                     compute_dtype="bfloat16", collective="quant-int8:64")
    pol = ExecutionPolicy.from_config(qc)
    assert pol.backend == "pallas"
    assert pol.compute_dtype == jnp.dtype(jnp.bfloat16)
    assert pol.collective == CollectiveSpec(name="quant-int8", block_size=64)


def test_from_config_bad_values_error():
    with pytest.raises(ValueError, match="unknown compute_dtype 'float64'"):
        ExecutionPolicy.from_config(QuantConfig(compute_dtype="float64"))
    with pytest.raises(ValueError, match="registered strategies"):
        ExecutionPolicy.from_config(QuantConfig(collective="allgather"))


def test_auto_heuristic():
    # pallas only when the layout is ordered AND we are on a real TPU
    assert ExecutionPolicy.auto("tp-aware", on_tpu=True).backend == "pallas"
    assert ExecutionPolicy.auto("exllama", on_tpu=True).backend == "pallas"
    assert ExecutionPolicy.auto("naive-actorder",
                                on_tpu=True).backend == "jnp"
    assert ExecutionPolicy.auto("tp-aware", on_tpu=False).backend == "jnp"


def test_policy_validates_and_hashes():
    with pytest.raises(ValueError, match="unknown scheme"):
        ExecutionPolicy(scheme="nope")
    with pytest.raises(ValueError, match="unknown collective"):
        ExecutionPolicy(collective="allgather")
    # hashable + stable under dtype spelling (static-arg requirement)
    a = ExecutionPolicy(compute_dtype=jnp.float32)
    b = ExecutionPolicy(compute_dtype=np.float32)
    assert a == b and hash(a) == hash(b)
    assert hash(ExecutionPolicy().with_tiling(block_m=64)) != hash(
        ExecutionPolicy())
    # string shorthands normalize to the same spec (hash-stable)
    c = ExecutionPolicy(collective="cast:bfloat16")
    d = ExecutionPolicy(collective=CollectiveSpec.parse("cast"))
    assert c == d and hash(c) == hash(d)


def test_policy_has_no_stringly_reduce_fields():
    """The redesign's contract: the collective plan is a CollectiveSpec,
    not loose strings."""
    fields = {f.name for f in dataclasses.fields(ExecutionPolicy)}
    assert "reduce" not in fields and "reduce_dtype" not in fields
    assert isinstance(DEFAULT_POLICY.collective, CollectiveSpec)
    qfields = {f.name for f in dataclasses.fields(QuantConfig)}
    assert "reduce" not in qfields and "reduce_dtype" not in qfields


# ---------------------------------------------------------------------------
# dispatch registry
# ---------------------------------------------------------------------------

def test_dispatch_resolves_all_seeded_pairs():
    pp, x = _mk_pair(0, 64, 64, 32, 32, "tp-aware")
    layouts = {"ordered": pp.up, "naive": None}
    res_naive, _ = _mk_pair(0, 64, 64, 32, 32, "naive-actorder")
    layouts["naive"] = res_naive.up
    for kind, ql in layouts.items():
        y_ref = None
        for backend in ("ref", "jnp", "pallas"):
            assert backend in dispatch.backends(kind)
            fn = dispatch.resolve(kind, backend)
            pol = ExecutionPolicy(backend=backend)
            y = np.asarray(fn(x, ql, pol))
            assert y.shape == (8, 64)
            if y_ref is None:
                y_ref = y
            else:
                np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-3)


def test_dispatch_unknown_backend_errors():
    with pytest.raises(ValueError, match="no kernel registered"):
        dispatch.resolve("ordered", "cuda")
    with pytest.raises(ValueError, match="registered backends"):
        dispatch.resolve("naive", "not-a-backend")
    with pytest.raises(ValueError, match="unknown layout kind"):
        dispatch.register("diagonal", "jnp")


def test_dispatch_extensible():
    """New backends register themselves and immediately become valid
    policy values — the redesign's extensibility contract."""
    @dispatch.register("ordered", "_test_double")
    def _double(x, ql, policy):
        jnp_fn = dispatch.resolve("ordered", "jnp")
        return 2.0 * jnp_fn(x, ql, policy)

    try:
        pp, x = _mk_pair(1, 64, 64, 32, 32, "tp-aware", gate=False)
        y1 = pp.forward(x, ExecutionPolicy(backend="jnp"))
        y2 = pp.forward(x, ExecutionPolicy(backend="_test_double"))
        # both GEMMs of the (gateless) pair double -> output is 4x
        np.testing.assert_allclose(np.asarray(y2), 4.0 * np.asarray(y1),
                                   rtol=1e-6)
    finally:
        del dispatch._REGISTRY[("ordered", "_test_double")]


# ---------------------------------------------------------------------------
# default policy == explicit spelling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", reorder.SCHEMES)
@pytest.mark.parametrize("gate", [True, False])
def test_forward_default_policy_is_explicit_policy(scheme, gate):
    """Omitting the policy, DEFAULT_POLICY, and the fully-spelled-out
    equivalent are bit-identical (the historical default plan)."""
    pp, x = _mk_pair(7, 128, 256, 128, 32, scheme, gate)
    y_default = np.asarray(pp.forward(x, activation="silu"))
    y_explicit = np.asarray(schemes.pair_forward_reference(
        x, pp, ExecutionPolicy(scheme=scheme, backend="jnp",
                               compute_dtype=jnp.float32,
                               collective="psum"),
        activation="silu"))
    np.testing.assert_array_equal(y_default, y_explicit)
    np.testing.assert_array_equal(
        np.asarray(pp.forward(x, DEFAULT_POLICY, activation="silu")),
        y_default)
    assert resolve_policy(None) is DEFAULT_POLICY
    assert resolve_policy(y_pol := ExecutionPolicy(backend="ref")) is y_pol


# ---------------------------------------------------------------------------
# context / engine plumbing
# ---------------------------------------------------------------------------

def test_parallel_context_policy_threading():
    from repro.models.common import ParallelContext, REPLICATED

    assert REPLICATED.execution_policy == DEFAULT_POLICY
    explicit = ParallelContext(policy=ExecutionPolicy(collective="none"))
    assert explicit.execution_policy.collective == CollectiveSpec("none")
    quant = ParallelContext(policy=ExecutionPolicy(
        collective="quant-int8"))
    assert quant.execution_policy.collective.name == "quant-int8"
    # the deprecated per-field spelling is gone for good
    with pytest.raises(TypeError):
        ParallelContext(mlp_reduce="psum_scatter")


def test_engine_injects_policy_into_ctx():
    from repro.configs import get_smoke_config
    from repro.runtime.serve import make_engine

    cfg = get_smoke_config("granite-3-8b").with_quant(mode="mlp")
    eng = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16)
    assert eng.policy == ExecutionPolicy.from_config(cfg)
    assert eng.ctx.policy == eng.policy
    # an explicit policy wins
    pol = ExecutionPolicy(backend="ref")
    eng2 = make_engine(cfg, jax.random.PRNGKey(0), max_seq=16, policy=pol)
    assert eng2.ctx.policy == pol


def test_tiling_flows_to_kernel():
    """KernelTiling is part of the policy and reaches the pallas wrapper."""
    pp, x = _mk_pair(5, 128, 128, 64, 32, "tp-aware", gate=False)
    pol = ExecutionPolicy(backend="pallas", tiling=KernelTiling(
        block_m=32, block_n=64, block_k=64, interpret=True))
    y = pp.forward(x, pol)
    y_ref = pp.forward(x, ExecutionPolicy(backend="ref"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)
    # block_k is honored, not silently dropped: an un-tileable K errors
    bad = ExecutionPolicy(backend="pallas", tiling=KernelTiling(
        block_m=32, block_n=64, block_k=48, interpret=True))
    with pytest.raises(ValueError, match="bad tiling"):
        jax.block_until_ready(pp.forward(x, bad))


def test_engine_conflicting_policies_error():
    from repro.configs import get_smoke_config
    from repro.models.common import ParallelContext
    from repro.runtime.serve import make_engine

    cfg = get_smoke_config("granite-3-8b").with_quant(mode="mlp")
    ctx = ParallelContext(policy=ExecutionPolicy(backend="ref"))
    with pytest.raises(ValueError, match="conflicting deployment plans"):
        make_engine(cfg, jax.random.PRNGKey(0), ctx=ctx, max_seq=16,
                    policy=ExecutionPolicy(backend="jnp"))
    # matching policies are fine
    eng = make_engine(cfg, jax.random.PRNGKey(0), ctx=ctx, max_seq=16,
                      policy=ExecutionPolicy(backend="ref"))
    assert eng.ctx.policy.backend == "ref"


def test_policy_replace_helpers():
    pol = DEFAULT_POLICY.with_(backend="ref").with_tiling(block_m=8)
    assert pol.backend == "ref" and pol.tiling.block_m == 8
    assert DEFAULT_POLICY.tiling.block_m == 128   # frozen originals
    quant = DEFAULT_POLICY.with_(collective="quant-int8")
    assert quant.collective == CollectiveSpec.parse("quant-int8")
    assert DEFAULT_POLICY.collective == CollectiveSpec("psum")
