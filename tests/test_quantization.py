"""Unit + property tests for GPTQ-style group quantization (core/)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as qz


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    q = rng.integers(0, 16, size=(64, 24)).astype(np.int32)
    packed = qz.pack_int4(jnp.asarray(q))
    assert packed.shape == (8, 24) and packed.dtype == jnp.uint32
    out = qz.unpack_int4(packed)
    np.testing.assert_array_equal(np.asarray(out), q)


def test_choose_group_size():
    assert qz.choose_group_size(1024, 128) == 128
    assert qz.choose_group_size(608, 128) == 76
    assert qz.choose_group_size(100, 128) == 100
    assert qz.choose_group_size(304, 128) == 76
    with pytest.raises(ValueError):
        qz.choose_group_size(0)


def test_rtn_error_bound():
    """|W - dq(q(W))| <= scale/2 per element (RTN with exact zero point)."""
    rng = jax.random.PRNGKey(0)
    w = jax.random.normal(rng, (128, 32))
    res = qz.quantize(w, group_size=32, act_order=False)
    dq = qz.dequantize(res.naive)
    g_idx = jnp.arange(128) // 32
    bound = jnp.take(res.naive.scales, g_idx, axis=0) * 0.5 + 1e-6
    assert bool(jnp.all(jnp.abs(w - dq) <= bound))


def test_actorder_layouts_equivalent():
    """naive and ordered layouts dequantize to the same logical matrix."""
    rng = jax.random.PRNGKey(1)
    w = jax.random.normal(rng, (256, 16))
    res = qz.quantize(w, group_size=64, act_order=True, rng=rng)
    dq_naive = qz.dequantize(res.naive)                 # original order
    dq_sorted = qz.dequantize(res.ordered)              # sorted rows
    # scatter sorted rows back to original positions
    restored = jnp.zeros_like(dq_sorted).at[res.perm].set(dq_sorted)
    np.testing.assert_allclose(np.asarray(dq_naive), np.asarray(restored),
                               rtol=0, atol=0)


def test_g_idx_matches_eq3():
    """g_idx[i] = floor(phi(i) / G) for the emulated permutation (Eq. 3)."""
    rng = jax.random.PRNGKey(2)
    k, g = 128, 32
    w = jax.random.normal(rng, (k, 8))
    res = qz.quantize(w, group_size=g, act_order=True, rng=rng)
    g_idx = np.asarray(res.g_idx)
    # every group must contain exactly G rows
    counts = np.bincount(g_idx, minlength=k // g)
    assert (counts == g).all()
    # perm sorts g_idx
    assert (np.diff(g_idx[np.asarray(res.perm)]) >= 0).all()


def test_importance_actorder_groups_by_importance():
    """High-importance rows land in the first quant groups."""
    k, g = 64, 16
    rng = jax.random.PRNGKey(3)
    w = jax.random.normal(rng, (k, 4))
    imp = jnp.arange(k, dtype=jnp.float32)          # row k-1 most important
    res = qz.quantize(w, group_size=g, act_order=True, importance=imp)
    g_idx = np.asarray(res.g_idx)
    # the 16 most important rows (largest indices) must be group 0
    assert (g_idx[-g:] == 0).all()


def test_gptq_hessian_reduces_error():
    """GPTQ error feedback beats RTN on a correlated-input quadratic loss."""
    rng = jax.random.PRNGKey(4)
    k, n, g = 64, 32, 16
    r1, r2 = jax.random.split(rng)
    w = jax.random.normal(r1, (k, n))
    x = jax.random.normal(r2, (512, k))
    # correlated calibration inputs
    mix = jnp.eye(k) + 0.4 * jax.random.normal(jax.random.PRNGKey(5), (k, k)) / k ** 0.5
    xc = x @ mix
    h = qz.make_hessian(xc)

    res_rtn = qz.quantize(w, g, act_order=False, use_gptq=False)
    res_gptq = qz.quantize(w, g, act_order=False, use_gptq=True, hessian=h)

    y = xc @ w
    err_rtn = jnp.mean(jnp.square(y - xc @ qz.dequantize(res_rtn.naive)))
    err_gptq = jnp.mean(jnp.square(y - xc @ qz.dequantize(res_gptq.naive)))
    assert float(err_gptq) < float(err_rtn)


def test_actorder_with_hessian_importance_reduces_error():
    """desc_act (process important rows first) reduces task error further."""
    rng = jax.random.PRNGKey(6)
    k, n, g = 64, 32, 16
    r1, r2 = jax.random.split(rng)
    w = jax.random.normal(r1, (k, n))
    # skewed input importance: some channels much larger
    scale_vec = jnp.exp(jnp.linspace(0, 3, k))
    x = jax.random.normal(r2, (512, k)) * scale_vec
    h = qz.make_hessian(x)

    res_plain = qz.quantize(w, g, act_order=False, use_gptq=True, hessian=h)
    res_ao = qz.quantize(w, g, act_order=True, use_gptq=True, hessian=h)

    y = x @ w
    err_plain = jnp.mean(jnp.square(y - x @ qz.dequantize(res_plain.naive)))
    err_ao = jnp.mean(jnp.square(y - x @ qz.dequantize(res_ao.naive)))
    assert float(err_ao) < float(err_plain)


def test_permute_columns_commutes():
    """Column permutation of the packed form == permuting dequantized W."""
    rng = jax.random.PRNGKey(7)
    w = jax.random.normal(rng, (64, 48))
    res = qz.quantize(w, 16, act_order=True, rng=rng)
    p = jax.random.permutation(jax.random.PRNGKey(8), 48)
    dq_then_perm = qz.dequantize(res.ordered)[:, p]
    perm_then_dq = qz.dequantize(qz.permute_columns(res.ordered, p))
    np.testing.assert_array_equal(np.asarray(dq_then_perm),
                                  np.asarray(perm_then_dq))
