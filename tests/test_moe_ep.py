"""Explicit-EP MoE dispatch (shard_map + all_to_all) correctness.

The EP path must equal the single-device reference exactly when no token
is capacity-dropped (drop *sets* legitimately differ between global and
per-rank capacity accounting, so the comparison pins capacity high).
"""

import os
import subprocess
import sys
import textwrap

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_moe_ep_matches_reference_no_drops():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.registry import build_model
        from repro.models.common import ParallelContext, REPLICATED

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for aid in ("qwen3-moe-235b-a22b", "arctic-480b"):
            cfg = get_smoke_config(aid).with_(capacity_factor=64.0)
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            batch = m.make_batch(jax.random.PRNGKey(1), 4, 16)
            y_ref = np.asarray(
                m.forward(params, batch, REPLICATED).astype(jnp.float32))
            ctx = ParallelContext(mesh=mesh, batch_axes=("data",))
            with mesh:
                y_ep = np.asarray(jax.jit(
                    lambda p, b: m.forward(p, b, ctx))(
                        params, batch).astype(jnp.float32))
            err = np.abs(y_ep - y_ref).max() / (np.abs(y_ref).max() + 1e-6)
            assert err < 5e-3, (aid, err)
            print("OK", aid, err)
    """)
    assert out.count("OK") == 2


def test_moe_ep_emits_all_to_all():
    """The EP path's collective schedule contains the two all_to_alls."""
    out = _run("""
        import jax, jax.numpy as jnp, re
        from repro.configs import get_smoke_config
        from repro.models.registry import build_model
        from repro.models.common import ParallelContext

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("qwen3-moe-235b-a22b")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = m.make_batch(jax.random.PRNGKey(1), 4, 16)
        ctx = ParallelContext(mesh=mesh, batch_axes=("data",))
        with mesh:
            txt = jax.jit(lambda p, b: m.forward(p, b, ctx)).lower(
                params, batch).compile().as_text()
        n = len(re.findall(r" all-to-all(?:-start)?\\(", txt))
        assert n >= 2, f"expected >=2 all-to-alls, found {n}"
        print("OK", n)
    """)
    assert "OK" in out


def test_moe_within_expert_collective_resolves_from_plan():
    """The within-expert epilogue resolves "layers.moe.experts" from a
    per-layer CollectivePlan like every other pair — a compressed
    full-output strategy applies (bounded error), while ``none`` /
    scatter strategies fall back to psum (the EP combine needs every
    rank's complete expert output), bit-identical to the psum plan."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.core.policy import ExecutionPolicy
        from repro.models.registry import build_model
        from repro.models.common import ParallelContext

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("qwen3-moe-235b-a22b").with_(
            capacity_factor=64.0)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = m.make_batch(jax.random.PRNGKey(1), 4, 16)

        def run(coll):
            ctx = ParallelContext(mesh=mesh, batch_axes=("data",),
                                  policy=ExecutionPolicy(collective=coll))
            with mesh:
                return np.asarray(jax.jit(
                    lambda p, b: m.forward(p, b, ctx))(
                        params, batch).astype(jnp.float32))

        y_psum = run("psum")
        y_none = run("per-layer:*.experts=none,*=psum")
        np.testing.assert_array_equal(y_psum, y_none)
        print("OK none-falls-back-to-psum")

        y_q = run("per-layer:*.experts=quant-int8:32,*=psum")
        err = np.abs(y_q - y_psum).max() / (np.abs(y_psum).max() + 1e-6)
        assert 0 < err < 5e-2, err    # compressed wire genuinely applied
        print("OK quantized-within-expert", f"{err:.1e}")
    """)
    assert out.count("OK") == 2
