"""Beyond-paper head-block-constrained attention fold (core/attention_fold).

Exactness claim: permuting V's columns within each KV head's block and
out_proj's rows by the induced (block-constrained) order commutes with
attention, so the folded quantized pipeline equals the unfolded quantized
pipeline bit-for-bit (same codes, different layout).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention_fold as af, quantization as qz


def _setup(seed, h, kv, hd, d, b=2, s=6, gs=None):
    rng = jax.random.PRNGKey(seed)
    r = jax.random.split(rng, 4)
    w_v = jax.random.normal(r[0], (d, kv * hd))
    w_o = jax.random.normal(r[1], (h * hd, d))
    x = jax.random.normal(r[2], (b, s, d))
    aw = jax.nn.softmax(jax.random.normal(r[3], (b, h, s, s)), axis=-1)
    pp = af.plan_attention_vo(w_v, w_o, n_heads=h, n_kv_heads=kv,
                              head_dim=hd, group_size=gs or hd, rng=rng)
    return pp, x, aw, (w_v, w_o)


def _unfolded_reference(pp, x, aw, h, kv, hd):
    """Same quantized weights, original layout, no fold."""
    g = h // kv
    wv = qz.dequantize(pp.up)
    wv_rows_orig = jnp.zeros_like(wv).at[pp.p1_up].set(wv)
    wo_sorted = qz.dequantize(pp.down)
    wo_orig = jnp.zeros_like(wo_sorted).at[pp.p2].set(wo_sorted)
    # undo the column fold on V: the fold permuted each KV block by pi;
    # recover pi from the first q head of each KV group (q head layout is
    # kv-major: head (kv_i, g_j) sits at index kv_i*g + g_j)
    pi = jnp.stack([pp.p2[i * g * hd:i * g * hd + hd] % hd
                    for i in range(kv)])
    kv_fold = (jnp.arange(kv)[:, None] * hd + pi).reshape(-1)
    wv_unfolded = jnp.zeros_like(wv_rows_orig).at[:, kv_fold].set(
        wv_rows_orig)
    b, s, _ = x.shape
    v = (x @ wv_unfolded).reshape(b, s, kv, hd)
    out = jnp.einsum("bhst,bthd->bshd", aw, jnp.repeat(v, g, axis=2))
    return out.reshape(b, s, h * hd) @ wo_orig


@pytest.mark.parametrize("h,kv,hd", [(8, 2, 32), (4, 4, 16), (8, 1, 32)])
def test_fold_exact(h, kv, hd):
    d = 64
    pp, x, aw, _ = _setup(h * 10 + kv, h, kv, hd, d)
    y_fold = af.attention_vo_reference(x, None, aw, pp, n_heads=h,
                                       n_kv_heads=kv, head_dim=hd)
    y_ref = _unfolded_reference(pp, x, aw, h, kv, hd)
    scale = float(jnp.abs(y_ref).max())
    np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_ref),
                               atol=5e-5 * scale)


def test_constrained_order_stays_in_blocks():
    h, kv, hd = 8, 2, 32
    imp = jax.random.uniform(jax.random.PRNGKey(0), (h * hd,))
    order, pi = af.constrained_row_order(imp, n_heads=h, n_kv_heads=kv,
                                         head_dim=hd)
    order = np.asarray(order)
    for head in range(h):
        blk = order[head * hd:(head + 1) * hd]
        assert (blk // hd == head).all()       # never leaves its block
    # q heads of the same KV group share the permutation
    g = h // kv
    pi0 = order[:hd] % hd
    for qh in range(1, g):
        np.testing.assert_array_equal(order[qh * hd:(qh + 1) * hd] % hd, pi0)


def test_group_size_must_tile_head_dim():
    with pytest.raises(ValueError, match="tile head_dim"):
        af.plan_attention_vo(jnp.zeros((64, 64)), jnp.zeros((128, 64)),
                             n_heads=4, n_kv_heads=2, head_dim=32,
                             group_size=48)


# ---------------------------------------------------------------------------
# runtime consumption: the model attention executes the fold (aux plans)
# ---------------------------------------------------------------------------

def _effective_dense_weights(vo):
    """The fold's closed function as plain dense weights:
    ``v = take(x, p1) @ W_up  ==  x @ scatter_rows(W_up, p1)`` and
    ``y = out @ W_down`` (tp-aware: the P2 fold happened offline)."""
    wv = qz.dequantize(vo.up)
    if vo.p1_up is not None:
        wv = jnp.zeros_like(wv).at[vo.p1_up].set(wv)
    return wv, qz.dequantize(vo.down)


def test_attention_runtime_consumes_vo_fold():
    """attention_forward/attention_decode with ``vo=`` run the precompiled
    fold: equal (to f32 GEMM tolerance) to the dense path with the fold's
    effective dequantized weights — the commutation argument end to end,
    inside the real model attention (RoPE, GQA, qk-norm, cache)."""
    from repro.configs import get_smoke_config
    from repro.models import common as cm
    from repro.models.common import REPLICATED

    cfg = get_smoke_config("qwen3-4b")
    p = cm.attention_params(cfg, jax.random.PRNGKey(0))
    hd = cfg.head_dim
    kvp, _, hp = cm.head_grid(cfg)
    gs = qz.choose_group_size(hd, cfg.quant.group_size)
    vo = af.plan_attention_vo(p["wv"], p["wo"], n_heads=hp, n_kv_heads=kvp,
                              head_dim=hd, group_size=gs,
                              rng=jax.random.PRNGKey(7))
    wv_eff, wo_eff = _effective_dense_weights(vo)
    p_eff = dict(p, wv=wv_eff, wo=wo_eff)

    x = jax.random.normal(jax.random.PRNGKey(3), (2, 6, cfg.d_model))
    y_vo = cm.attention_forward(cfg, p, x, REPLICATED, vo=vo)
    y_eff = cm.attention_forward(cfg, p_eff, x, REPLICATED)
    scale = float(jnp.abs(y_eff).max())
    np.testing.assert_allclose(np.asarray(y_vo), np.asarray(y_eff),
                               atol=1e-4 * max(scale, 1.0))

    cache = {"k": jnp.zeros((2, 8, kvp, hd)), "v": jnp.zeros((2, 8, kvp, hd))}
    y1, c1 = cm.attention_decode(cfg, p, x[:, :1], dict(cache),
                                 jnp.int32(0), REPLICATED, vo=vo)
    y2, c2 = cm.attention_decode(cfg, p_eff, x[:, :1], dict(cache),
                                 jnp.int32(0), REPLICATED)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4 * max(scale, 1.0))
    # folded V channels land in the cache — permuted within head blocks
    # relative to the dense cache, identical as a (sorted) multiset
    np.testing.assert_allclose(
        np.sort(np.asarray(c1["v"][:, 0]), axis=-1),
        np.sort(np.asarray(c2["v"][:, 0]), axis=-1), atol=1e-4)


def test_engine_serves_artifact_aux_folds():
    """An artifact prepared with ``attn_tp_aware`` serves through the
    fold: the engine closes over the aux plans, prefill/decode run, and
    the logits differ from the no-aux engine (quantized V/O path)."""
    from repro.configs import get_smoke_config
    from repro.plan import compiler
    from repro.runtime.serve import Engine, make_engine

    cfg = get_smoke_config("qwen3-4b").with_quant(attn_tp_aware=True)
    art = compiler.prepare(cfg, tp=1, seed=0)
    assert art.aux and "attn_plans" in art.aux

    eng = make_engine(cfg, artifact=art, max_seq=32)
    assert eng.aux is not None
    tokens = jnp.array([[1, 2, 3, 4]])
    logits_fold = eng._prefill(eng.params, {"tokens": tokens})

    plain = Engine(model=eng.model, params=eng.params, max_seq=32)
    logits_plain = plain._prefill(plain.params, {"tokens": tokens})
    assert float(jnp.max(jnp.abs(logits_fold - logits_plain))) > 0

    cache = eng.init_cache(1)
    lg, _ = eng._decode(eng.params, cache, jnp.array([3]), jnp.int32(0))
    assert lg.shape == (1, cfg.vocab_size)


def test_vision_llama_decoder_consumes_vo_fold():
    """The VLM family threads artifact aux folds into its decoder
    self-attention (``SUPPORTS_ATTN_VO``): ``stage_fold_attention``
    plans both the self and cross attention dicts, the runtime consumes
    the ``super.self.attn`` path in forward AND decode, and the folded
    logits differ from the no-aux path (quantized V/O pipeline)."""
    from repro.configs import get_smoke_config
    from repro.models.common import REPLICATED
    from repro.models.registry import build_model
    from repro.plan import compiler

    cfg = get_smoke_config("llama-3.2-vision-90b").with_quant(
        attn_tp_aware=True)
    model = build_model(cfg)
    assert model.supports_attn_vo
    art = compiler.prepare(cfg, tp=1, seed=0)
    plans = art.aux["attn_plans"]
    # the fold stage walks the whole tree: decoder self layers (stacked
    # (n_super, n_self)) and the gated cross layers both get plans
    assert "super.self.attn" in plans and "super.cross.xattn" in plans
    assert plans["super.self.attn"].up.qweight.ndim == 4  # 2 stack dims

    params = art.params()
    batch = model.make_batch(jax.random.PRNGKey(1), 2, 6)
    y_vo = model.forward(params, batch, REPLICATED, aux=art.aux)
    y_plain = model.forward(params, batch, REPLICATED)
    assert y_vo.shape == (2, 6, cfg.vocab_size)
    assert float(jnp.max(jnp.abs(y_vo - y_plain))) > 0

    cache = model.init_cache(2, 8)
    lg, cache2 = model.decode_step(params, cache, batch["tokens"][:, 0],
                                   jnp.int32(0), REPLICATED, aux=art.aux)
    lg_plain, _ = model.decode_step(params, cache, batch["tokens"][:, 0],
                                    jnp.int32(0), REPLICATED)
    assert lg.shape == (2, cfg.vocab_size)
    assert float(jnp.max(jnp.abs(lg - lg_plain))) > 0
