"""Beyond-paper head-block-constrained attention fold (core/attention_fold).

Exactness claim: permuting V's columns within each KV head's block and
out_proj's rows by the induced (block-constrained) order commutes with
attention, so the folded quantized pipeline equals the unfolded quantized
pipeline bit-for-bit (same codes, different layout).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention_fold as af, quantization as qz


def _setup(seed, h, kv, hd, d, b=2, s=6, gs=None):
    rng = jax.random.PRNGKey(seed)
    r = jax.random.split(rng, 4)
    w_v = jax.random.normal(r[0], (d, kv * hd))
    w_o = jax.random.normal(r[1], (h * hd, d))
    x = jax.random.normal(r[2], (b, s, d))
    aw = jax.nn.softmax(jax.random.normal(r[3], (b, h, s, s)), axis=-1)
    pp = af.plan_attention_vo(w_v, w_o, n_heads=h, n_kv_heads=kv,
                              head_dim=hd, group_size=gs or hd, rng=rng)
    return pp, x, aw, (w_v, w_o)


def _unfolded_reference(pp, x, aw, h, kv, hd):
    """Same quantized weights, original layout, no fold."""
    g = h // kv
    wv = qz.dequantize(pp.up)
    wv_rows_orig = jnp.zeros_like(wv).at[pp.p1_up].set(wv)
    wo_sorted = qz.dequantize(pp.down)
    wo_orig = jnp.zeros_like(wo_sorted).at[pp.p2].set(wo_sorted)
    # undo the column fold on V: the fold permuted each KV block by pi;
    # recover pi from the first q head of each KV group (q head layout is
    # kv-major: head (kv_i, g_j) sits at index kv_i*g + g_j)
    pi = jnp.stack([pp.p2[i * g * hd:i * g * hd + hd] % hd
                    for i in range(kv)])
    kv_fold = (jnp.arange(kv)[:, None] * hd + pi).reshape(-1)
    wv_unfolded = jnp.zeros_like(wv_rows_orig).at[:, kv_fold].set(
        wv_rows_orig)
    b, s, _ = x.shape
    v = (x @ wv_unfolded).reshape(b, s, kv, hd)
    out = jnp.einsum("bhst,bthd->bshd", aw, jnp.repeat(v, g, axis=2))
    return out.reshape(b, s, h * hd) @ wo_orig


@pytest.mark.parametrize("h,kv,hd", [(8, 2, 32), (4, 4, 16), (8, 1, 32)])
def test_fold_exact(h, kv, hd):
    d = 64
    pp, x, aw, _ = _setup(h * 10 + kv, h, kv, hd, d)
    y_fold = af.attention_vo_reference(x, None, aw, pp, n_heads=h,
                                       n_kv_heads=kv, head_dim=hd)
    y_ref = _unfolded_reference(pp, x, aw, h, kv, hd)
    scale = float(jnp.abs(y_ref).max())
    np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_ref),
                               atol=5e-5 * scale)


def test_constrained_order_stays_in_blocks():
    h, kv, hd = 8, 2, 32
    imp = jax.random.uniform(jax.random.PRNGKey(0), (h * hd,))
    order, pi = af.constrained_row_order(imp, n_heads=h, n_kv_heads=kv,
                                         head_dim=hd)
    order = np.asarray(order)
    for head in range(h):
        blk = order[head * hd:(head + 1) * hd]
        assert (blk // hd == head).all()       # never leaves its block
    # q heads of the same KV group share the permutation
    g = h // kv
    pi0 = order[:hd] % hd
    for qh in range(1, g):
        np.testing.assert_array_equal(order[qh * hd:(qh + 1) * hd] % hd, pi0)


def test_group_size_must_tile_head_dim():
    with pytest.raises(ValueError, match="tile head_dim"):
        af.plan_attention_vo(jnp.zeros((64, 64)), jnp.zeros((128, 64)),
                             n_heads=4, n_kv_heads=2, head_dim=32,
                             group_size=48)
