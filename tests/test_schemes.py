"""Exactness of the three deployment schemes (paper Algorithms 1-3).

The paper's central correctness claim: naive-actorder, exllama (Alg. 1/2)
and tp-aware (Alg. 3) are *the same arithmetic* — only data layout and
communication differ.  Outputs must agree to f32 reduction tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as qz, reorder, schemes


def _mk_pair(seed, k1, n1, n2, gs, scheme, gate=True):
    rng = jax.random.PRNGKey(seed)
    r = jax.random.split(rng, 4)
    w_up = jax.random.normal(r[0], (k1, n1))
    w_gate = jax.random.normal(r[1], (k1, n1)) if gate else None
    w_down = jax.random.normal(r[2], (n1, n2))
    pp = reorder.plan_pair(w_up, w_down, w_gate=w_gate, scheme=scheme,
                           group_size_up=gs, group_size_down=gs, rng=rng)
    x = jax.random.normal(r[3], (8, k1))
    return pp, x, (w_up, w_gate, w_down)


def test_reorder_function():
    """Algorithm 1: returns (P, sorted g_idx)."""
    g_idx = jnp.asarray([2, 0, 1, 0, 2, 1], jnp.int32)
    p, sorted_g = reorder.reorder(g_idx)
    assert (np.diff(np.asarray(sorted_g)) >= 0).all()
    np.testing.assert_array_equal(np.asarray(g_idx)[np.asarray(p)],
                                  np.asarray(sorted_g))


@pytest.mark.parametrize("gate", [True, False])
@pytest.mark.parametrize("act", ["silu", "gelu", "relu2"])
def test_schemes_same_arithmetic(gate, act):
    outs = {}
    for scheme in reorder.SCHEMES:
        pp, x, _ = _mk_pair(0, 128, 256, 128, 64, scheme, gate)
        outs[scheme] = np.asarray(
            schemes.pair_forward_reference(x, pp, activation=act))
    ref = outs["naive-actorder"]
    scale = np.abs(ref).max()
    for scheme in ("exllama", "tp-aware"):
        np.testing.assert_allclose(outs[scheme], ref, atol=2e-4 * scale,
                                   err_msg=scheme)


def test_quantization_close_to_fp():
    pp, x, (w_up, w_gate, w_down) = _mk_pair(1, 128, 256, 128, 32,
                                             "tp-aware")
    y_q = schemes.pair_forward_reference(x, pp, activation="silu")
    y_fp = (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
    rel = float(jnp.abs(y_q - y_fp).max() / jnp.abs(y_fp).max())
    assert rel < 0.5, rel   # int4 group quant on random normals


def test_shard_pair_slices_consistent():
    """shard_pair shards reproduce the full pair's dequantized weights."""
    pp, x, _ = _mk_pair(2, 128, 256, 128, 32, "tp-aware")
    tp = 4
    shards = reorder.shard_pair(pp, tp)
    n_shard = pp.n1 // tp
    w_up_full = qz.dequantize(pp.up)
    w_down_full = qz.dequantize(pp.down)
    for r, sh in enumerate(shards):
        np.testing.assert_array_equal(
            np.asarray(qz.dequantize(sh.up)),
            np.asarray(w_up_full[:, r * n_shard:(r + 1) * n_shard]))
        np.testing.assert_array_equal(
            np.asarray(qz.dequantize(sh.down)),
            np.asarray(w_down_full[r * n_shard:(r + 1) * n_shard]))
        np.testing.assert_array_equal(
            np.asarray(sh.p2),
            np.asarray(pp.p2[r * n_shard:(r + 1) * n_shard]))


def test_shard_pair_group_misalignment_raises():
    pp, _, _ = _mk_pair(3, 128, 256, 128, 64, "tp-aware")
    with pytest.raises(ValueError, match="not aligned"):
        reorder.shard_pair(pp, 8)   # 256/8 = 32 < group 64


def test_sharded_forward_matches_full():
    """Manually-sharded per-rank compute (paper Alg. 3 data flow) == full."""
    pp, x, _ = _mk_pair(4, 128, 256, 128, 32, "tp-aware")
    tp = 4
    shards = reorder.shard_pair(pp, tp)
    y_full = schemes.pair_forward_reference(x, pp, activation="silu")
    acc = 0.0
    for sh in shards:
        # per-rank: local up/gate GEMM -> act -> local down GEMM; then SUM
        acc = acc + schemes.pair_forward_reference(x, sh, activation="silu")
    np.testing.assert_allclose(np.asarray(acc), np.asarray(y_full),
                               atol=2e-4 * float(np.abs(y_full).max()))


def test_shared_p1_gather(recwarn):
    """share_p1 (beyond-paper): gate quantized in up's processing order —
    one runtime gather serves both column-TP GEMMs, outputs unchanged."""
    rng = jax.random.PRNGKey(11)
    r = jax.random.split(rng, 4)
    w_up = jax.random.normal(r[0], (128, 256))
    w_gate = jax.random.normal(r[1], (128, 256))
    w_down = jax.random.normal(r[2], (256, 128))
    x = jax.random.normal(r[3], (8, 128))

    pp_shared = reorder.plan_pair(w_up, w_down, w_gate=w_gate,
                                  scheme="tp-aware", group_size_up=32,
                                  group_size_down=32, rng=rng, share_p1=True)
    pp_sep = reorder.plan_pair(w_up, w_down, w_gate=w_gate,
                               scheme="tp-aware", group_size_up=32,
                               group_size_down=32, rng=rng, share_p1=False)
    assert pp_shared.p1_gate is None
    assert pp_sep.p1_gate is not None
    y_shared = schemes.pair_forward_reference(x, pp_shared, activation="silu")
    y_sep = schemes.pair_forward_reference(x, pp_sep, activation="silu")
    # same arithmetic up to quantization-grouping differences of the gate
    scale = float(np.abs(np.asarray(y_sep)).max())
    np.testing.assert_allclose(np.asarray(y_shared), np.asarray(y_sep),
                               atol=0.2 * scale)
    # and exactly equal to the unquantized-order-independent naive scheme
    pp_naive = reorder.plan_pair(w_up, w_down, w_gate=w_gate,
                                 scheme="naive-actorder", group_size_up=32,
                                 group_size_down=32, rng=rng, share_p1=True)
    y_naive = schemes.pair_forward_reference(x, pp_naive, activation="silu")
    np.testing.assert_allclose(np.asarray(y_shared), np.asarray(y_naive),
                               atol=3e-4 * scale)
