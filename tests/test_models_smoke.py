"""Per-arch smoke tests: reduced same-family config, one forward + one
decode step on CPU, asserting output shapes and finiteness (assignment
requirement: 2 layers, d_model<=512, <=4 experts)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.common import REPLICATED, head_grid
from repro.models.registry import build_model


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_decode(arch_id):
    cfg = get_smoke_config(arch_id)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), 2, 16)

    logits = m.forward(params, batch, REPLICATED)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    cache = m.init_cache(2, 32)
    lg, new_cache = m.decode_step(params, cache, batch["tokens"][:, 0],
                                  jnp.int32(0), REPLICATED)
    assert lg.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The full configs carry the exact assigned dimensions."""
    expected = {
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    }[arch_id]
    cfg = get_config(arch_id)
    got = (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)
    assert cfg.source  # citation present
    if arch_id == "qwen3-moe-235b-a22b":
        assert (cfg.num_experts, cfg.top_k) == (128, 8)
    if arch_id == "arctic-480b":
        assert (cfg.num_experts, cfg.top_k) == (128, 2)
        assert cfg.dense_residual


def test_decode_greedy_consistent_with_forward():
    """Decoding token-by-token reproduces the forward logits (KV-cache
    correctness), for a dense arch."""
    cfg = get_smoke_config("granite-3-8b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), 2, 8)
    ref_logits = m.forward(params, batch, REPLICATED)   # (2, 8, V)

    cache = m.init_cache(2, 16)
    outs = []
    for t in range(8):
        lg, cache = m.decode_step(params, cache, batch["tokens"][:, t],
                                  jnp.int32(t), REPLICATED)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    err = jnp.abs(dec_logits - ref_logits).max()
    scale = jnp.abs(ref_logits).max()
    assert float(err) < 2e-2 * float(scale), float(err / scale)


def test_decode_state_consistent_rwkv():
    """Recurrent-state decode == parallel forward for the SSM arch."""
    cfg = get_smoke_config("rwkv6-3b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), 2, 8)
    ref_logits = m.forward(params, batch, REPLICATED)

    cache = m.init_cache(2, 16)
    outs = []
    for t in range(8):
        lg, cache = m.decode_step(params, cache, batch["tokens"][:, t],
                                  jnp.int32(t), REPLICATED)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    err = jnp.abs(dec_logits - ref_logits).max()
    scale = jnp.abs(ref_logits).max()
    assert float(err) < 2e-2 * float(scale), float(err / scale)


def test_padded_head_grid_is_exact():
    """attn_tp_pad pads the head grid with zero weights: the function is
    EXACTLY the logical architecture's (same PRNG draws for real heads)."""
    base = get_smoke_config("starcoder2-3b")   # kv=2, heads don't divide 8
    padded = base.with_(attn_tp_pad=8)
    assert head_grid(padded)[2] % 8 == 0
    assert head_grid(padded) != head_grid(base)

    m0, m1 = build_model(base), build_model(padded)
    p0 = m0.init(jax.random.PRNGKey(0))
    p1 = m1.init(jax.random.PRNGKey(0))
    batch = m0.make_batch(jax.random.PRNGKey(1), 2, 16)
    y0 = m0.forward(p0, batch, REPLICATED).astype(jnp.float32)
    y1 = m1.forward(p1, batch, REPLICATED).astype(jnp.float32)
    err = float(jnp.abs(y0 - y1).max())
    # padded heads change bf16 reduction trees; exact in f32, ~2e-3 in bf16
    assert err < 5e-3 * float(jnp.abs(y0).max()), err


def test_sliding_window_decode_bounded_cache():
    """window decode: cache capacity = window, positions past it reuse
    slots (ring buffer) without shape growth."""
    cfg = get_smoke_config("mistral-large-123b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    window = 8
    cache = m.init_cache(2, 64, window=window)
    assert cache["k"].shape[2] == window
    tok = jnp.zeros((2,), jnp.int32)
    for t in range(12):   # run past the window
        lg, cache = m.decode_step(params, cache, tok, jnp.int32(t),
                                  REPLICATED, window=window)
    assert cache["k"].shape[2] == window
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())
