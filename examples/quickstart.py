"""Quickstart: the paper's three deployment schemes on one MLP pair.

Shows the whole story in ~80 lines:
  1. quantize a (gate/up -> down) pair with act_order (GPTQ Eq. 3),
  2. describe each deployment as one ``ExecutionPolicy`` (scheme, kernel
     backend, dtypes, TP collective spec),
  3. run ``PlannedPair.forward(x, policy, mesh=...)`` — the canonical
     runtime entry point — and verify all three compute the same function,
  4. count the collectives each one needs under tensor parallelism,
  5. swap the trailing collective for a *quantized* one
     (``collective="quant-int8"``) and compare wire bytes and error.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reorder
from repro.core.policy import ExecutionPolicy
from repro.launch import roofline

K1, N1, N2, M, TP = 512, 1024, 512, 8, 4

rng = jax.random.PRNGKey(0)
r = jax.random.split(rng, 4)
w_gate = jax.random.normal(r[0], (K1, N1)) * 0.02
w_up = jax.random.normal(r[1], (K1, N1)) * 0.02
w_down = jax.random.normal(r[2], (N1, N2)) * 0.02
x = jax.random.normal(r[3], (M, K1))

print(f"MLP pair: ({K1}, {N1}) -> ({N1}, {N2}), batch {M}, TP={TP}\n")

mesh = jax.make_mesh((len(jax.devices()) // TP, TP), ("data", "model"))
outs = {}
for scheme in ("naive-actorder", "exllama", "tp-aware"):
    # offline: quantize int4 (group 128, act_order) + lay out for `scheme`
    pp = reorder.plan_pair(w_up, w_down, w_gate=w_gate, scheme=scheme,
                           group_size_up=128, group_size_down=128, rng=rng)
    # the deployment plan as one object: layout scheme + kernel backend
    # (auto: pallas on TPU for ordered layouts, jnp here) + collective
    policy = ExecutionPolicy.auto(scheme)
    # online: tensor-parallel forward with explicit collectives
    with mesh:
        fn = lambda xx, p=pp, pol=policy: p.forward(
            xx, pol, mesh, activation="silu")
        y = jax.jit(fn)(x)
        hlo = jax.jit(fn).lower(x).compile().as_text()
    outs[scheme] = np.asarray(y)
    coll = roofline.parse_collective_bytes(hlo, chips=mesh.devices.size)
    print(f"{scheme:15s} collectives: "
          + ", ".join(f"{k}={v}" for k, v in coll["counts"].items() if v)
          + f"  ({roofline.fmt_bytes(coll['total_per_device'])}/device)")

print("\nmax |tp-aware - naive| =",
      np.abs(outs["tp-aware"] - outs["naive-actorder"]).max(),
      "(same arithmetic, different layout/communication)")
print("max |exllama  - naive| =",
      np.abs(outs["exllama"] - outs["naive-actorder"]).max())

# --- communication compression: a quantized trailing collective -----------
# The collective is a CollectiveSpec on the policy, dispatched by the
# comm/dispatch registry — swapping the f32 AllReduce for a blockwise-int8
# one is a one-field change, no model code involved.
from repro.comm import CollectiveSpec

pp = reorder.plan_pair(w_up, w_down, w_gate=w_gate, scheme="tp-aware",
                       group_size_up=128, group_size_down=128, rng=rng)
print(f"\ntrailing collective on the ({M}, {N2}) partials at TP={TP}:")
for shorthand in ("psum", "cast:bfloat16", "quant-int8"):
    spec = CollectiveSpec.parse(shorthand)
    policy = ExecutionPolicy.auto("tp-aware", collective=spec)
    with mesh:
        y = np.asarray(jax.jit(
            lambda xx: pp.forward(xx, policy, mesh, activation="silu"))(x))
    err = np.abs(y - outs["tp-aware"]).max() / np.abs(outs["tp-aware"]).max()
    print(f"  {shorthand:14s} "
          f"{roofline.fmt_bytes(spec.bytes_on_wire((M, N2), TP)):>8s}/device"
          f"  rel_err={err:.1e}")
