"""Quickstart: the paper's three deployment schemes on one MLP pair.

Shows the whole story in ~60 lines:
  1. quantize a (gate/up -> down) pair with act_order (GPTQ Eq. 3),
  2. describe each deployment as one ``ExecutionPolicy`` (scheme, kernel
     backend, dtypes, TP collective strategy),
  3. run ``PlannedPair.forward(x, policy, mesh=...)`` — the canonical
     runtime entry point — and verify all three compute the same function,
  4. count the collectives each one needs under tensor parallelism.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reorder
from repro.core.policy import ExecutionPolicy
from repro.launch import roofline

K1, N1, N2, M, TP = 512, 1024, 512, 8, 4

rng = jax.random.PRNGKey(0)
r = jax.random.split(rng, 4)
w_gate = jax.random.normal(r[0], (K1, N1)) * 0.02
w_up = jax.random.normal(r[1], (K1, N1)) * 0.02
w_down = jax.random.normal(r[2], (N1, N2)) * 0.02
x = jax.random.normal(r[3], (M, K1))

print(f"MLP pair: ({K1}, {N1}) -> ({N1}, {N2}), batch {M}, TP={TP}\n")

mesh = jax.make_mesh((len(jax.devices()) // TP, TP), ("data", "model"))
outs = {}
for scheme in ("naive-actorder", "exllama", "tp-aware"):
    # offline: quantize int4 (group 128, act_order) + lay out for `scheme`
    pp = reorder.plan_pair(w_up, w_down, w_gate=w_gate, scheme=scheme,
                           group_size_up=128, group_size_down=128, rng=rng)
    # the deployment plan as one object: layout scheme + kernel backend
    # (auto: pallas on TPU for ordered layouts, jnp here) + collective
    policy = ExecutionPolicy.auto(scheme)
    # online: tensor-parallel forward with explicit collectives
    with mesh:
        fn = lambda xx, p=pp, pol=policy: p.forward(
            xx, pol, mesh, activation="silu")
        y = jax.jit(fn)(x)
        hlo = jax.jit(fn).lower(x).compile().as_text()
    outs[scheme] = np.asarray(y)
    coll = roofline.parse_collective_bytes(hlo, chips=mesh.devices.size)
    print(f"{scheme:15s} collectives: "
          + ", ".join(f"{k}={v}" for k, v in coll["counts"].items() if v)
          + f"  ({roofline.fmt_bytes(coll['total_per_device'])}/device)")

print("\nmax |tp-aware - naive| =",
      np.abs(outs["tp-aware"] - outs["naive-actorder"]).max(),
      "(same arithmetic, different layout/communication)")
print("max |exllama  - naive| =",
      np.abs(outs["exllama"] - outs["naive-actorder"]).max())
