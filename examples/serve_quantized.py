"""Serve a quantized model with batched requests through the scheduler —
the prepare-once / serve-many lifecycle under a (data=2, model=4) host
mesh.

Step 1 (offline, once per deployment): the plan compiler quantizes,
reorders/folds, and pre-shards the weights for the target TP degree,
freezing a ``DeploymentArtifact`` directory.

Step 2 (every server start): load + validate the artifact and serve.  No
GPTQ, no ``plan_pair`` at startup — the manifest guarantees the plan
matches the config, policy, and mesh.

Run:  PYTHONPATH=src python examples/serve_quantized.py [--arch qwen3-4b]
      (add --one-shot to compile in memory instead, the old flow;
       add --http to front the same engine with the streaming HTTP/SSE
       server from DESIGN.md §8 and replay the requests over the wire)
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.policy import ExecutionPolicy
from repro.models.common import ParallelContext
from repro.plan import DeploymentArtifact, compiler
from repro.runtime.sampling import SamplingConfig
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.serve import make_engine

TP = 4


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--scheme", default="tp-aware")
    ap.add_argument("--collective", default="psum",
                    help="trailing collective spec (comm.dispatch registry "
                         "shorthand, e.g. psum, psum_scatter, "
                         "cast:bfloat16, quant-int8, quant-int4) or a "
                         "per-layer plan, e.g. "
                         "'per-layer:*.mlp=quant-int8:128,*=psum'")
    ap.add_argument("--autotune-collectives", action="store_true",
                    help="let the plan compiler pick a per-layer "
                         "CollectivePlan (analytic bytes + calibration "
                         "error probe; overrides --collective) — only "
                         "meaningful with the prepare/serve two-step")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--artifact", default=None,
                    help="reuse an existing artifact dir (skips prepare)")
    ap.add_argument("--one-shot", action="store_true",
                    help="compile the plan in memory at startup instead "
                         "of the prepare/serve two-step")
    ap.add_argument("--http", action="store_true",
                    help="serve over the HTTP/SSE front end (ephemeral "
                         "port) and stream the requests as SSE events "
                         "instead of driving the scheduler directly")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).with_quant(mode="mlp",
                                                 scheme=args.scheme,
                                                 collective=args.collective)
    # the deployment plan, derived once from the config and threaded
    # through the engine to every quantized GEMM
    policy = ExecutionPolicy.from_config(cfg)

    artifact = None
    if not args.one_shot:
        # ---- step 1: prepare (offline compile; skipped when an artifact
        # directory is supplied) --------------------------------------------
        art_dir = args.artifact
        if art_dir is None:
            art_dir = os.path.join(tempfile.mkdtemp(prefix="repro_plan_"),
                                   args.arch)
            t0 = time.time()
            compiler.prepare(cfg, tp=TP, seed=0, policy=policy,
                             extra_manifest={"smoke": True},
                             autotune=args.autotune_collectives
                             ).save(art_dir)
            print(f"prepared artifact in {time.time() - t0:.1f}s "
                  f"-> {art_dir}")
        # ---- step 2: load + validate (no quantization from here on) -------
        artifact = DeploymentArtifact.load(art_dir)
        # the manifest is the source of truth for the plan (it may carry
        # a tuned per-layer CollectivePlan the CLI flags don't know)
        policy = artifact.policy()

    mesh = jax.make_mesh((2, TP), ("data", "model"))
    ctx = ParallelContext(mesh=mesh, batch_axes=("data",), policy=policy)
    print(f"arch={args.arch} scheme={args.scheme} backend={policy.backend} "
          f"collective={policy.collective.shorthand()} "
          f"mesh=2x{TP} (data x model) "
          f"{'one-shot compile' if args.one_shot else 'from artifact'}")
    if artifact is not None:
        for site in artifact.manifest.get("collective_tuner", ()):
            # ':fused' sites run the wire-epilogue kernel: the down GEMM
            # emits ring phase 1's quantized payload (DESIGN.md §10)
            print(f"  site {site['path']} [{site.get('kind', 'pair')}] -> "
                  f"{site['chosen']}"
                  + (" (fused wire epilogue)" if site.get("fused") else ""))
        if artifact.aux:
            print(f"  aux plans: {', '.join(artifact.aux)} "
                  "(attention V->O folds served)")

    with mesh:
        engine = make_engine(cfg, jax.random.PRNGKey(0), ctx=ctx,
                             max_seq=48, policy=policy, artifact=artifact)
        if args.http:
            return _serve_http(engine, cfg, args)
        sched = Scheduler(engine, max_batch=4, prompt_budget=16,
                          scfg=SamplingConfig(temperature=0.7, top_k=40))
        rng = np.random.default_rng(0)
        t0 = time.time()
        for i in range(args.requests):
            plen = int(rng.integers(3, 16))
            sched.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=plen).astype(np.int32),
                max_new_tokens=args.max_new))
        done = sched.run()
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in done.values())
    mid = sum(1 for step, _ in sched.admissions if step > 0)
    for rid, r in sorted(done.items()):
        print(f"  req {rid}: prompt[{len(r.prompt):2d}] -> {r.output}")
    print(f"\n{len(done)} requests ({mid} admitted mid-stream), "
          f"{tokens} new tokens, {dt:.1f}s "
          f"({tokens / dt:.1f} tok/s on CPU interpret)")


def _serve_http(engine, cfg, args):
    """Front the engine with the SSE server and replay the synthetic
    requests over real HTTP connections (one thread per client)."""
    import http.client
    import json
    import threading

    from repro.runtime.sampling import SamplingConfig
    from repro.serving import ServingServer

    srv = ServingServer(engine, max_batch=4, prompt_budget=16,
                        scfg=SamplingConfig(temperature=0.7, top_k=40),
                        queue_capacity=8).start()
    print(f"HTTP/SSE front end on http://127.0.0.1:{srv.port} "
          "(POST /v1/generate, GET /v1/health, GET /v1/stats)")
    rng = np.random.default_rng(0)
    bodies = []
    for i in range(args.requests):
        plen = int(rng.integers(3, 16))
        bodies.append({"prompt": rng.integers(0, cfg.vocab_size,
                                              size=plen).tolist(),
                       "max_new_tokens": args.max_new, "seed": i})
    t0 = time.time()

    def one(i):
        body = bodies[i]
        plen = len(body["prompt"])
        conn = http.client.HTTPConnection("127.0.0.1", srv.port,
                                          timeout=300)
        conn.request("POST", "/v1/generate", json.dumps(body),
                     {"Content-Type": "application/json"})
        toks = []
        for line in conn.getresponse():
            if line.startswith(b"data: "):
                payload = json.loads(line[6:])
                if "token" in payload:
                    toks.append(payload["token"])
                elif "usage" in payload:
                    u = payload["usage"]
                    print(f"  req {i}: prompt[{plen:2d}] -> {toks} "
                          f"(ttft {u['ttft_ms']:.0f}ms)")
        conn.close()
        return len(toks)

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(args.requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = srv.loop.stats()
    srv.shutdown()
    dt = time.time() - t0
    tok = stats["tokens"]["generated"]
    print(f"\n{stats['requests']['completed']} requests over HTTP, "
          f"{tok} new tokens, {dt:.1f}s ({tok / dt:.1f} tok/s), "
          f"ttft p50 {stats['latency_ms']['ttft'].get('p50')}ms")


if __name__ == "__main__":
    main()
