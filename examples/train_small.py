"""End-to-end driver: train a ~small model for a few hundred steps on the
synthetic corpus, checkpoint it, quantize it with the TP-aware plan, and
compare dense vs int4 deployment logits.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
(~100M-param variant: --dmodel 768 --layers 12 — slower on CPU.)
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.common import REPLICATED
from repro.models.registry import build_model
from repro.quant.gptq import quantize_model
from repro.train import checkpoint, data as data_lib, optimizer as opt
from repro.train import trainstep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--dmodel", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--ckpt", default="results/train_small")
    args = ap.parse_args()

    cfg = get_smoke_config("granite-3-8b").with_(
        num_layers=args.layers, d_model=args.dmodel,
        d_ff=args.dmodel * 2).with_quant(mode="none")
    model = build_model(cfg)
    nparams = sum(x.size for x in jax.tree.leaves(model.init(
        jax.random.PRNGKey(0))))
    print(f"model: {cfg.arch_id} family={cfg.family} "
          f"L={cfg.num_layers} d={cfg.d_model} params={nparams / 1e6:.1f}M")

    ocfg = opt.AdamWConfig(lr=1e-3, total_steps=args.steps,
                           warmup_steps=args.steps // 20)
    state = trainstep.init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(trainstep.make_train_step(model, REPLICATED, ocfg),
                   donate_argnums=0)
    dcfg = data_lib.DataConfig(seq_len=args.seq, global_batch=args.batch,
                               vocab_size=cfg.vocab_size)
    it = data_lib.batches(dcfg)

    t0 = time.time()
    first = None
    for i in range(args.steps):
        state, metrics = step(state, next(it))
        if first is None:
            first = float(metrics["loss"])
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    final = float(metrics["loss"])
    print(f"\nloss: {first:.3f} -> {final:.3f} "
          f"({'improved' if final < first else 'NO IMPROVEMENT'})")

    path = checkpoint.save(args.ckpt, state["params"], step=args.steps)
    print("checkpoint:", path)

    # deployment: quantize the trained model with the TP-aware plan
    qcfg = cfg.with_quant(mode="mlp", scheme="tp-aware")
    qparams = quantize_model(qcfg, state["params"])
    qmodel = build_model(qcfg)
    batch = model.make_batch(jax.random.PRNGKey(9), 2, args.seq)
    y_dense = model.forward(state["params"], batch, REPLICATED)
    y_int4 = qmodel.forward(qparams, batch, REPLICATED)
    d = float(jnp.abs(y_dense.astype(jnp.float32)
                      - y_int4.astype(jnp.float32)).max())
    print(f"dense vs int4(tp-aware) logits max|diff| = {d:.4f} "
          f"(scale {float(jnp.abs(y_dense).max()):.2f})")


if __name__ == "__main__":
    main()
