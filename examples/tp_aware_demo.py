"""The paper's core insight, step by step, with tiny matrices you can read.

Demonstrates WHY folding P2 into W1's columns removes the AllGather:
prints the actual index alignment between the column-TP output shards and
the row-TP weight shards under each scheme.

Run:  PYTHONPATH=src python examples/tp_aware_demo.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as qz, reorder

K1, N1, N2, G, TP = 16, 32, 16, 8, 2

rng = jax.random.PRNGKey(42)
r = jax.random.split(rng, 3)
w1 = jax.random.normal(r[0], (K1, N1))
w2 = jax.random.normal(r[1], (N1, N2))
x = jax.random.normal(r[2], (1, K1))

print(f"W1 ({K1}x{N1}) column-TP, W2 ({N1}x{N2}) row-TP, {TP} ranks, "
      f"group size {G}\n")

# --- quantize W2 with act_order: rows get an arbitrary processing order ---
q2 = qz.quantize(w2, G, act_order=True, rng=rng)
print("W2 unordered g_idx (Eq. 3):", np.asarray(q2.g_idx))
p2, g_sorted = reorder.reorder(q2.g_idx)
print("Algorithm 1: P2 =", np.asarray(p2))
print("             g_idx[P2] =", np.asarray(g_sorted),
      "(groups contiguous -> metadata loaded once per group)\n")

# --- the alignment problem -------------------------------------------------
# Exllama layout stores W2's rows sorted by P2.  Under TP, rank r holds
# W2_sorted rows [r*N1/TP : (r+1)*N1/TP] = original rows P2[r*N1/TP : ...].
# But rank r's local Y1 chunk holds original channels [r*N1/TP : ...] —
# they DON'T match, hence Alg. 2's AllGather + global permute + re-chunk.
half = N1 // TP
print("rank 0 W2-shard consumes Y1 channels:", np.asarray(p2[:half]))
print("rank 0 Y1 shard produces channels   :", list(range(half)))
print("  -> misaligned: Algorithm 2 must AllGather Y1 and permute by P2\n")

# --- the paper's fix: fold P2 into W1's columns offline --------------------
# Now rank 0's local GEMM produces exactly channels P2[:half], pre-aligned
# with its W2 row shard.  No AllGather, no permute — only the final psum.
print("TP-Aware (Alg. 3): W1 columns pre-permuted by P2 offline")
print("rank 0 Y1 shard now produces channels:", np.asarray(p2[:half]),
      " == its W2 shard's rows\n")

# --- numerical proof --------------------------------------------------------
# One ExecutionPolicy describes the runtime contract (kernel backend,
# dtypes, collective); PlannedPair.forward(x, policy) is the entry point.
from repro.core.policy import ExecutionPolicy

for scheme in ("naive-actorder", "exllama", "tp-aware"):
    pp = reorder.plan_pair(w1, w2, scheme=scheme, group_size_up=G,
                           group_size_down=G, rng=rng)
    shards = reorder.shard_pair(pp, TP) if scheme == "tp-aware" else None
    policy = ExecutionPolicy.auto(scheme)

    y = pp.forward(x, policy)
    if shards:
        # simulate per-rank compute + final AllReduce by hand
        y_tp = sum(s.forward(x, policy) for s in shards)
        print(f"{scheme:15s} y[0,:4] = {np.asarray(y)[0, :4].round(3)}   "
              f"(per-rank sum matches: "
              f"{np.allclose(np.asarray(y_tp), np.asarray(y), atol=1e-3)})")
    else:
        print(f"{scheme:15s} y[0,:4] = {np.asarray(y)[0, :4].round(3)}")
