"""Paper Tables 1-28: Naive Algorithm (Alg. 2) vs TP-Aware (Alg. 3) on the
paper's MLP problem sizes, swept over batch size and TP degree.

Two measurements per point:
* CPU wall time (relative only — this container has no TPU; the paper's
  absolute ms are not reproducible, the *trend* speedup-grows-with-TP is)
* collective bytes from the lowered shard_map HLO (exact, hardware-
  independent — the quantity the paper's speedup is made of), and the
  derived TPU-model speedup  t_naive/t_tpaware with
  t = max(t_compute, t_memory) + t_collective on v5e constants.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER_BATCH_SIZES, PAPER_PROBLEMS
from repro.core import reorder
from repro.core.policy import ExecutionPolicy
from repro.launch import roofline


def _plan(k1, n1, n2, scheme, gs=128):
    rng = jax.random.PRNGKey(0)
    r = jax.random.split(rng, 2)
    # paper benchmarks the up->down pair without gate (section 3)
    w_up = jax.random.normal(r[0], (k1, n1), jnp.float32) * 0.02
    w_down = jax.random.normal(r[1], (n1, n2), jnp.float32) * 0.02
    return reorder.plan_pair(w_up, w_down, scheme=scheme,
                             group_size_up=gs, group_size_down=gs, rng=rng)


def _mesh(tp):
    n = len(jax.devices())
    return jax.make_mesh((max(n // tp, 1), tp), ("data", "model"),
                         devices=jax.devices()[:max(n // tp, 1) * tp])


def _bench_wall(fn, *args, iters=3):
    y = fn(*args)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters * 1e6    # us


def _collective_bytes(fn, args, mesh):
    lowered = jax.jit(fn).lower(*args)
    txt = lowered.compile().as_text()
    return roofline.parse_collective_bytes(txt, chips=mesh.devices.size)


def tpu_model_time(m, k1, n1, n2, tp, coll_per_dev):
    """v5e single-chip model: max(compute, weight-read) + collective."""
    flops = 2 * m * (k1 * n1 + n1 * n2) / tp
    wbytes = (k1 * n1 + n1 * n2) / 2 / tp          # int4 weights
    t_c = flops / roofline.PEAK_FLOPS
    t_m = wbytes / roofline.HBM_BW
    t_coll = coll_per_dev / roofline.ICI_BW
    return max(t_c, t_m) + t_coll


def run(out_lines: list):
    title = "# bench_mlp: paper problem sizes, Naive(Alg.2) vs TP-Aware(Alg.3)"
    print(title)
    out_lines.append(title)
    title = f"# devices: {len(jax.devices())}"
    print(title)
    out_lines.append(title)
    header = ("problem,M,TP,scheme,wall_us,coll_bytes_per_dev,"
              "tpu_model_ms,tpu_model_speedup")
    print(header)
    out_lines.append(header)

    for pname, (k1, n1, n2) in PAPER_PROBLEMS.items():
        # quantize once per scheme (paper: offline), reuse across TP/M
        plans = {s: jax.block_until_ready(_plan(k1, n1, n2, s))
                 for s in ("exllama", "tp-aware")}
        for tp in (1, 2, 4, 8):
            if tp > len(jax.devices()):
                continue
            mesh = _mesh(tp)
            for m in PAPER_BATCH_SIZES:
                x = jax.random.normal(jax.random.PRNGKey(1), (m, k1),
                                      jnp.float32)
                res = {}
                for scheme, pp in plans.items():
                    pol = ExecutionPolicy(scheme=scheme, backend="jnp",
                                          compute_dtype=jnp.float32)
                    # pp passed as a jit ARGUMENT (not closure) so XLA
                    # cannot constant-fold the dequantization at compile
                    with mesh:
                        fn = lambda xx, p, pol=pol: p.forward(
                            xx, pol, mesh, activation=None)
                        coll = _collective_bytes(fn, (x, pp), mesh)
                        wall = (_bench_wall(jax.jit(fn), x, pp)
                                if m == 8 else float("nan"))
                    t_model = tpu_model_time(
                        m, k1, n1, n2, tp, coll["total_per_device"])
                    res[scheme] = (wall, coll["total_per_device"], t_model)
                sp = res["exllama"][2] / res["tp-aware"][2]
                for scheme in ("exllama", "tp-aware"):
                    wall, coll_b, t_model = res[scheme]
                    line = (f"{pname},{m},{tp},{scheme},{wall:.0f},"
                            f"{coll_b:.0f},{t_model * 1e3:.4f},"
                            f"{sp if scheme == 'tp-aware' else 1.0:.2f}")
                    print(line)
                    out_lines.append(line)


if __name__ == "__main__":
    run([])
