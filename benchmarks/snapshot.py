"""Shared ``BENCH_*.json`` snapshot writer.

Every benchmark that wants its numbers *tracked across PRs* writes a
snapshot through here: a single JSON file at the repo root named
``BENCH_<name>.json`` carrying the git SHA, the benchmark's config, and
its metrics.  Committing the file per PR gives future re-anchors a perf
trajectory instead of a point measurement.

Two producers:

* ``benchmarks/bench_serve.py`` builds its metrics dict directly
  (arrival-rate sweeps -> p50/p99 TTFT / ITL / tok/s).
* ``benchmarks/run.py --json`` routes the existing table benches
  (bench_comm, bench_mlp, bench_kernels, ...) through
  ``tables_from_lines`` to turn their CSV transcript into structured
  ``{"tables": [...]}`` metrics.
"""

from __future__ import annotations

import json
import os
import subprocess
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_sha(short: bool = True) -> str:
    try:
        args = ["git", "rev-parse"] + (["--short"] if short else [])
        return subprocess.run(
            args + ["HEAD"], cwd=REPO_ROOT, capture_output=True,
            text=True, check=True, timeout=10).stdout.strip()
    except Exception:
        return "unknown"


def _environment() -> dict:
    try:
        import jax
        env = {"jax": jax.__version__,
               "backend": jax.default_backend(),
               "device_count": jax.device_count(),
               "process_count": jax.process_count()}
        # the DP×TP grid the numbers were taken on (DESIGN.md §11) —
        # single-host benches report the trivial dp1xtp<N> shape only
        # when a mesh plan was exported by the runner
        plan = os.environ.get("REPRO_MESH")
        if plan:
            env["mesh"] = plan
        return env
    except Exception:
        return {}


def write(name: str, *, config: dict, metrics: dict,
          out_dir: str = REPO_ROOT) -> str:
    """Write ``BENCH_<name>.json``; returns the path."""
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "bench": name,
        "git_sha": git_sha(),
        "created": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "environment": _environment(),
        "config": config,
        "metrics": metrics,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=False)
        f.write("\n")
    return path


def load(name: str, out_dir: str = REPO_ROOT) -> dict:
    with open(os.path.join(out_dir, f"BENCH_{name}.json")) as f:
        return json.load(f)


def tables_from_lines(lines) -> list[dict]:
    """Parse a bench transcript (the ``run(out_lines)`` accumulation:
    ``# title`` lines, CSV headers, CSV rows) into structured tables.

    Tolerant by construction — a line is a table title if it starts
    with ``#``, a header if it contains a comma while no table is open,
    a row if it contains a comma under an open header; anything else
    closes the current table.  Numeric cells are converted.
    """
    tables: list[dict] = []
    current = None
    for raw in lines:
        line = str(raw).strip()
        if not line or line.startswith("==="):
            current = None
            continue
        if line.startswith("#"):
            current = {"title": line.lstrip("# "), "columns": None,
                       "rows": []}
            tables.append(current)
            continue
        if "," not in line:
            current = None
            continue
        cells = [c.strip() for c in line.split(",")]
        if current is None or current["columns"] is None:
            if current is None:
                current = {"title": "", "columns": None, "rows": []}
                tables.append(current)
            current["columns"] = cells
            continue
        current["rows"].append([_cell(c) for c in cells])
    return [t for t in tables if t["columns"] is not None]


def _cell(text: str):
    for typ in (int, float):
        try:
            return typ(text)
        except ValueError:
            pass
    return text
