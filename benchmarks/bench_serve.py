import os
import sys

# TP sweeps need >1 host device; 8 matches the other benches (run.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

"""Serving load generator: Poisson arrivals against the live HTTP/SSE
front end (DESIGN.md §8), sweeping arrival rate x TP degree.

For each (tp, rate) point it fires ``n`` requests with exponential
inter-arrival times at a real ``ServingServer`` (the same stack
``launch.serve --http`` runs), streams every SSE response, and reports:

* **TTFT** p50/p99 — POST sent -> first ``token`` event (queue wait +
  prefill replay included: this is what a client sees);
* **ITL** p50/p99 — gap between consecutive ``token`` events of one
  request;
* **throughput** — completed tokens / wall-clock of the sweep;
* **rejected** — 429 backpressure responses (the admission queue is
  deliberately small enough for the saturated rate to shed load).

Results land in ``BENCH_serve.json`` at the repo root via
``benchmarks/snapshot.py`` (git SHA + config + metrics) so the serving
perf trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
      [--rates 2,8,32] [--tp 1,2] [--requests 40]
"""

import argparse
import http.client
import json
import threading
import time

import numpy as np

from benchmarks import snapshot

ARCH = "qwen3-4b"
PROMPT_MIX = (4, 24)        # uniform prompt-length range
MAX_NEW_MIX = (4, 8, 16)    # cycled output lengths
MAX_BATCH = 4
QUEUE_CAPACITY = 16
PROMPT_BUDGET = 32


def _make_engine(tp: int, seed: int = 0, *, kv: str = "dense"):
    import jax

    from repro.cache import PageSpec
    from repro.configs import get_smoke_config
    from repro.core.policy import ExecutionPolicy
    from repro.launch import mesh as mesh_lib
    from repro.models.common import ParallelContext, REPLICATED
    from repro.runtime.serve import make_engine

    cfg = get_smoke_config(ARCH).with_quant(mode="mlp", scheme="tp-aware")
    policy = ExecutionPolicy.from_config(cfg).with_(kv=PageSpec.parse(kv))
    if tp > 1:
        mesh = mesh_lib.make_host_mesh(model=tp)
        ctx = ParallelContext(mesh=mesh, batch_axes=("data",),
                              policy=policy)
    else:
        ctx = REPLICATED
    engine = make_engine(cfg, jax.random.PRNGKey(seed), ctx=ctx,
                         max_seq=PROMPT_BUDGET + max(MAX_NEW_MIX) + 1,
                         policy=policy)
    return cfg, engine


def _serve(engine, seed: int = 0):
    from repro.runtime.sampling import SamplingConfig
    from repro.serving import ServingServer

    srv = ServingServer(engine, max_batch=MAX_BATCH,
                        prompt_budget=PROMPT_BUDGET,
                        scfg=SamplingConfig(temperature=0.0),
                        seed=seed, queue_capacity=QUEUE_CAPACITY,
                        retry_after=0.5)
    return srv.start()


def _make_server(tp: int, seed: int = 0):
    cfg, engine = _make_engine(tp, seed)
    return cfg, _serve(engine, seed)


def _stream_one(port: int, body: dict) -> dict:
    """POST one request, stream its SSE response, time every event."""
    rec = {"status": None, "tokens": 0, "ttft_ms": None, "itl_ms": []}
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=600)
    t0 = time.monotonic()
    try:
        conn.request("POST", "/v1/generate", json.dumps(body),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        rec["status"] = resp.status
        if resp.status != 200:
            resp.read()
            return rec
        last = None
        for line in resp:
            if not line.startswith(b"data: "):
                continue
            payload = json.loads(line[6:])
            if "token" in payload:
                now = time.monotonic()
                if last is None:
                    rec["ttft_ms"] = 1e3 * (now - t0)
                else:
                    rec["itl_ms"].append(1e3 * (now - last))
                last = now
                rec["tokens"] += 1
            elif "usage" in payload:
                rec["usage"] = payload["usage"]
    finally:
        conn.close()
    return rec


def _sweep(port: int, *, rate_rps: float, n: int, vocab: int,
           seed: int, bodies=None) -> dict:
    """Fire ``n`` Poisson arrivals at ``rate_rps``; aggregate client-side
    latency.  ``bodies`` overrides the default random request mix (the
    paged-cache sweep feeds workload-shaped prompts)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    if bodies is None:
        bodies = []
        for i in range(n):
            plen = int(rng.integers(*PROMPT_MIX))
            bodies.append({
                "prompt": rng.integers(0, vocab, size=plen).tolist(),
                "max_new_tokens": int(MAX_NEW_MIX[i % len(MAX_NEW_MIX)]),
                "temperature": 0.8, "top_p": 0.95, "seed": i,
            })
    n = len(bodies)
    records: list = [None] * n

    def client(i):
        records[i] = _stream_one(port, bodies[i])

    t0 = time.monotonic()
    threads = []
    for i in range(n):
        delay = arrivals[i] - (time.monotonic() - t0)
        if delay > 0:
            time.sleep(delay)
        th = threading.Thread(target=client, args=(i,))
        th.start()
        threads.append(th)
    for th in threads:
        th.join()
    wall = time.monotonic() - t0

    done = [r for r in records if r and r["status"] == 200]
    rejected = sum(1 for r in records if r and r["status"] == 429)
    ttft = [r["ttft_ms"] for r in done if r["ttft_ms"] is not None]
    itl = [x for r in done for x in r["itl_ms"]]
    tokens = sum(r["tokens"] for r in done)

    def pct(xs, p):
        return round(float(np.percentile(xs, p)), 2) if xs else None

    return {
        "rate_rps": rate_rps, "offered": n, "completed": len(done),
        "rejected_429": rejected, "wall_s": round(wall, 2),
        "tok_per_s": round(tokens / wall, 2) if wall else None,
        "ttft_ms": {"p50": pct(ttft, 50), "p99": pct(ttft, 99)},
        "itl_ms": {"p50": pct(itl, 50), "p99": pct(itl, 99)},
    }


def bench(rates, tps, n, *, seed: int = 0, out_lines=None):
    lines = out_lines if out_lines is not None else []
    header = ("tp,rate_rps,offered,completed,rejected_429,"
              "ttft_p50_ms,ttft_p99_ms,itl_p50_ms,itl_p99_ms,tok_per_s")
    print("# bench_serve: Poisson load vs the live HTTP/SSE front end "
          f"(arch={ARCH} smoke, max_batch={MAX_BATCH}, "
          f"queue={QUEUE_CAPACITY})")
    print(header)
    lines.append(header)
    sweeps = []
    for tp in tps:
        cfg, srv = _make_server(tp, seed)
        try:
            # warm-up: absorb decode-program compilation outside the
            # measured sweeps
            _stream_one(srv.port, {"prompt": [1, 2, 3],
                                   "max_new_tokens": 2})
            for rate in rates:
                s = _sweep(srv.port, rate_rps=rate, n=n,
                           vocab=cfg.vocab_size, seed=seed)
                s["tp"] = tp
                sweeps.append(s)
                row = (f"{tp},{rate:g},{s['offered']},{s['completed']},"
                       f"{s['rejected_429']},{s['ttft_ms']['p50']},"
                       f"{s['ttft_ms']['p99']},{s['itl_ms']['p50']},"
                       f"{s['itl_ms']['p99']},{s['tok_per_s']}")
                print(row)
                lines.append(row)
            stats = srv.loop.stats()
        finally:
            srv.shutdown(drain=False, timeout=10.0)
        sweeps[-1]["server_stats_after"] = {
            "requests": stats["requests"], "queue": stats["queue"]}
    return sweeps


# ----------------------------------------------------------------------
# paged-cache occupancy sweep (DESIGN.md §9) -> BENCH_paged.json
# ----------------------------------------------------------------------

#: cache layouts compared; page size 8 so the shared-prefix workload's
#: 24-token common prefix spans 3 complete (shareable) pages
KV_MODES = ("dense", "paged:8", "paged:8:int8")


def _workload_bodies(kind: str, vocab: int, n: int, seed: int) -> list:
    """Two cache-shaped workloads:

    * ``long-prompt`` — unique near-budget prompts: occupancy is pure
      live-token footprint (paging wins by not sizing for max_seq);
    * ``shared-prefix`` — one 24-token common prefix + a 4-token unique
      tail: complete prefix pages are shared and replay-skipped, so both
      peak bytes AND TTFT drop.
    """
    rng = np.random.default_rng(seed)
    bodies = []
    if kind == "long-prompt":
        for i in range(n):
            plen = int(rng.integers(PROMPT_BUDGET - 6, PROMPT_BUDGET))
            bodies.append({"prompt": rng.integers(0, vocab,
                                                  size=plen).tolist(),
                           "max_new_tokens": 8, "temperature": 0.0})
    else:
        prefix = rng.integers(0, vocab, size=24).tolist()
        for i in range(n):
            tail = rng.integers(0, vocab, size=4).tolist()
            bodies.append({"prompt": prefix + tail,
                           "max_new_tokens": 8, "temperature": 0.0})
    return bodies


def bench_paged(n: int, rate: float, *, seed: int = 0, out_lines=None):
    """Cache-occupancy sweep: kv layout x workload.  Each point gets a
    fresh server (fresh pool + counters) over a shared per-layout
    engine; reports client latency plus the server's own cache stats
    (peak live bytes vs the dense worst-case footprint, prefix hits)."""
    lines = out_lines if out_lines is not None else []
    header = ("kv,workload,completed,ttft_p50_ms,ttft_p99_ms,itl_p50_ms,"
              "peak_cache_bytes,dense_cache_bytes,prefix_hits,"
              "prefix_hit_rate")
    print(f"# bench_paged: cache occupancy x workload (arch={ARCH} "
          f"smoke, max_batch={MAX_BATCH}, rate={rate:g} rps)")
    print(header)
    lines.append(header)
    points = []
    for kv in KV_MODES:
        cfg, engine = _make_engine(1, seed, kv=kv)
        for wl in ("long-prompt", "shared-prefix"):
            srv = _serve(engine, seed)
            try:
                _stream_one(srv.port, {"prompt": [1, 2, 3],
                                       "max_new_tokens": 2})   # warm-up
                # drop the warm-up request's footprint from the counters
                srv.loop.scheduler.release_cache()
                bodies = _workload_bodies(wl, cfg.vocab_size, n, seed)
                s = _sweep(srv.port, rate_rps=rate, n=n,
                           vocab=cfg.vocab_size, seed=seed, bodies=bodies)
                cache = srv.loop.stats()["cache"]
            finally:
                srv.shutdown(drain=False, timeout=10.0)
            if "pages" in cache:
                peak = cache["bytes"]["peak_live"]
                dense_eq = cache["bytes"]["dense_equiv"]
                hits = cache["prefix"]["hits"]
                hit_rate = cache["prefix"]["hit_rate"]
            else:
                peak = dense_eq = cache["bytes"]["pool"]
                hits, hit_rate = 0, 0.0
            point = {"kv": kv, "workload": wl,
                     "completed": s["completed"],
                     "ttft_ms": s["ttft_ms"], "itl_ms": s["itl_ms"],
                     "tok_per_s": s["tok_per_s"],
                     "peak_cache_bytes": peak,
                     "dense_cache_bytes": dense_eq,
                     "prefix_hits": hits, "prefix_hit_rate": hit_rate}
            points.append(point)
            row = (f"{kv},{wl},{s['completed']},{s['ttft_ms']['p50']},"
                   f"{s['ttft_ms']['p99']},{s['itl_ms']['p50']},"
                   f"{peak},{dense_eq},{hits},{hit_rate}")
            print(row)
            lines.append(row)
    return points


def _write_paged_snapshot(points, *, n: int, rate: float) -> str:
    path = snapshot.write("paged", config={
        "arch": ARCH, "smoke": True, "scheme": "tp-aware",
        "max_batch": MAX_BATCH, "prompt_budget": PROMPT_BUDGET,
        "kv_modes": list(KV_MODES),
        "workloads": ["long-prompt", "shared-prefix"],
        "requests_per_point": n, "rate_rps": rate,
    }, metrics={"points": points})
    print(f"wrote {path}")
    return path


def run(out_lines: list, *, quick: bool = True):
    """run.py entry: quick sweep (tp=1 only) so the suite stays fast."""
    sweeps = bench((4.0, 16.0), (1,), 8, out_lines=out_lines)
    _write_snapshot(sweeps, quick=True)
    points = bench_paged(8, 8.0, out_lines=out_lines)
    _write_paged_snapshot(points, n=8, rate=8.0)


def _write_snapshot(sweeps, *, quick: bool) -> str:
    path = snapshot.write("serve", config={
        "arch": ARCH, "smoke": True, "scheme": "tp-aware",
        "max_batch": MAX_BATCH, "queue_capacity": QUEUE_CAPACITY,
        "prompt_budget": PROMPT_BUDGET,
        "prompt_mix": list(PROMPT_MIX), "max_new_mix": list(MAX_NEW_MIX),
        "sampling": {"temperature": 0.8, "top_p": 0.95,
                     "seed": "per-request"},
        "quick": quick,
    }, metrics={"sweeps": sweeps})
    print(f"wrote {path}")
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tp=1, two rates, few requests (CI smoke)")
    ap.add_argument("--paged-only", action="store_true",
                    help="run only the paged-cache occupancy sweep "
                         "(writes BENCH_paged.json)")
    ap.add_argument("--rates", default=None,
                    help="comma-separated arrival rates in req/s "
                         "(default 2,8,32; quick: 4,16)")
    ap.add_argument("--tp", default=None,
                    help="comma-separated TP degrees (default 1,2; "
                         "quick: 1)")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per sweep point (default 40; "
                         "quick: 8)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rates = (tuple(float(r) for r in args.rates.split(","))
             if args.rates else ((4.0, 16.0) if args.quick
                                 else (2.0, 8.0, 32.0)))
    tps = (tuple(int(t) for t in args.tp.split(","))
           if args.tp else ((1,) if args.quick else (1, 2)))
    n = args.requests or (8 if args.quick else 40)

    if not args.paged_only:
        sweeps = bench(rates, tps, n, seed=args.seed)
        _write_snapshot(sweeps, quick=args.quick)
    if args.paged_only or not args.quick:
        np_ = args.requests or (8 if args.quick else 16)
        rate = 8.0
        points = bench_paged(np_, rate, seed=args.seed)
        _write_paged_snapshot(points, n=np_, rate=rate)


if __name__ == "__main__":
    main()
