"""Collective-bytes accounting per scheme × TP degree (the paper's Figure
5-8 mechanism, measured exactly from lowered HLO rather than wall time).

The paper's claim: the Naive Algorithm's AllGather cost grows with rank
count while TP-Aware pays only the (unavoidable) trailing AllReduce —
hence speedup grows with TP.  Here the two schemes' per-device ICI bytes
are parsed from the compiled shard_map program; their ratio is the
communication-side speedup upper bound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import PAPER_PROBLEMS
from repro.core import reorder
from repro.core.policy import ExecutionPolicy
from repro.launch import roofline

from benchmarks.bench_mlp import _mesh, _plan, _collective_bytes


def run(out_lines: list):
    print("# bench_comm: per-device ICI bytes by scheme (M=8)")
    header = ("problem,TP,scheme,allgather_B,allreduce_B,total_B,"
              "vs_tpaware")
    print(header)
    out_lines.append(header)
    m = 8
    for pname, (k1, n1, n2) in PAPER_PROBLEMS.items():
        plans = {s: _plan(k1, n1, n2, s)
                 for s in ("naive-actorder", "exllama", "tp-aware")}
        for tp in (2, 4, 8):
            if tp > len(jax.devices()):
                continue
            mesh = _mesh(tp)
            x = jax.random.normal(jax.random.PRNGKey(1), (m, k1))
            res = {}
            for scheme, pp in plans.items():
                pol = ExecutionPolicy(scheme=scheme, backend="jnp",
                                      compute_dtype=jnp.float32)
                with mesh:
                    fn = lambda xx, p, pol=pol: p.forward(
                        xx, pol, mesh, activation=None)
                    coll = _collective_bytes(fn, (x, pp), mesh)
                res[scheme] = coll
            base = res["tp-aware"]["total_per_device"]
            for scheme, coll in res.items():
                line = (f"{pname},{tp},{scheme},{coll['all-gather']:.0f},"
                        f"{coll['all-reduce']:.0f},"
                        f"{coll['total_per_device']:.0f},"
                        f"{coll['total_per_device'] / max(base, 1):.2f}")
                print(line)
                out_lines.append(line)


if __name__ == "__main__":
    run([])
