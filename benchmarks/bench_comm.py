"""Collective-bytes accounting (the paper's Figure 5-8 mechanism, measured
exactly from lowered HLO rather than wall time) — three tables:

1. **per scheme × TP degree**: the paper's claim — the Naive Algorithm's
   AllGather cost grows with rank count while TP-Aware pays only the
   (unavoidable) trailing AllReduce, so their ratio is the comm-side
   speedup upper bound.

2. **per collective strategy × TP degree** (comm/dispatch registry): what
   the trailing collective itself costs under each registered
   ``CollectiveSpec`` — measured HLO bytes, the strategy's analytic
   ``bytes_on_wire`` model, their relative disagreement
   (``hlo_vs_model``: exactly 0 for psum/psum_scatter/quant-*; ``cast``
   reads 1.0 on CPU only, where XLA promotes the bf16 all-reduce to f32
   — the wire stays bf16 on TPU), the ratio vs the f32 ``psum``
   baseline, and the output's relative error vs the single-device
   reference.  This is the communication-compression table:
   ``quant-int8`` lands at ~(1 + 2/block)/4 ≈ 25% of the f32 psum bytes.

3. **fused wire epilogue vs plain quant**: the ``:fused`` spec routes
   the down GEMM through the Pallas wire-epilogue kernel (DESIGN.md
   §10); measured HLO collective bytes and outputs must be identical to
   the unfused strategy — the fusion saves HBM traffic inside the
   kernel, never wire bytes — both asserted per row.

4. **exposed vs overlapped quant ring**: the ``:overlap`` spec
   (DESIGN.md §11) pipelines the decomposed ppermute ring against the
   next microbatch's dequant-GEMM; outputs and wire bytes must be
   identical to the synchronous epilogue while
   ``roofline.parse_overlap_windows`` proves the compiled schedule
   issues the permutes with a GEMM inside their in-flight windows.

5. **per pair path under a ``CollectivePlan``**: the per-layer selection
   table — each pair resolves its own collective from the plan's glob
   map, shown with the lowered HLO's collective instruction counts
   (quant epilogues lower to all_to_all + all_gather phases, psum/cast
   to one all-reduce) proving the resolution happens per pair, plus
   measured and analytic bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import (CollectivePlan, CollectiveSpec,
                        dispatch as comm_dispatch)
from repro.configs import PAPER_PROBLEMS
from repro.core.policy import ExecutionPolicy
from repro.launch import roofline

from benchmarks.bench_mlp import _mesh, _plan, _collective_bytes


def _scheme_table(out_lines: list, m: int):
    title = "# bench_comm: per-device ICI bytes by scheme (M=8)"
    print(title)
    out_lines.append(title)
    header = ("problem,TP,scheme,allgather_B,allreduce_B,total_B,"
              "vs_tpaware")
    print(header)
    out_lines.append(header)
    for pname, (k1, n1, n2) in PAPER_PROBLEMS.items():
        plans = {s: _plan(k1, n1, n2, s)
                 for s in ("naive-actorder", "exllama", "tp-aware")}
        for tp in (2, 4, 8):
            if tp > len(jax.devices()):
                continue
            mesh = _mesh(tp)
            x = jax.random.normal(jax.random.PRNGKey(1), (m, k1))
            res = {}
            for scheme, pp in plans.items():
                pol = ExecutionPolicy(scheme=scheme, backend="jnp",
                                      compute_dtype=jnp.float32)
                with mesh:
                    fn = lambda xx, p, pol=pol: p.forward(
                        xx, pol, mesh, activation=None)
                    coll = _collective_bytes(fn, (x, pp), mesh)
                res[scheme] = coll
            base = res["tp-aware"]["total_per_device"]
            for scheme, coll in res.items():
                line = (f"{pname},{tp},{scheme},{coll['all-gather']:.0f},"
                        f"{coll['all-reduce']:.0f},"
                        f"{coll['total_per_device']:.0f},"
                        f"{coll['total_per_device'] / max(base, 1):.2f}")
                print(line)
                out_lines.append(line)


def _strategy_table(out_lines: list, m: int):
    """Trailing-collective cost/error per registered strategy (tp-aware
    layout, so the epilogue is the ONLY collective in the program).

    ``hlo_B`` is parsed from the compiled program, ``model_B`` is the
    strategy's analytic ``bytes_on_wire``; ``hlo_vs_model`` is their
    relative disagreement — exactly 0 for psum / psum_scatter /
    quant-int8 / quant-int4 (tiling and non-tiling dims alike: both the
    implementation and the accounting are the padded two-phase ring).
    For ``cast`` the CPU backend promotes the bf16 all-reduce to f32
    (hlo_vs_model = 1.0) — on TPU the wire stays bf16, which is what
    the model column accounts."""
    title = "# bench_comm: trailing collective by strategy (M=8, tp-aware)"
    print(title)
    out_lines.append(title)
    header = ("problem,TP,collective,hlo_B,model_B,hlo_vs_model,"
              "vs_psum,rel_err")
    print(header)
    out_lines.append(header)
    for pname, (k1, n1, n2) in PAPER_PROBLEMS.items():
        pp = _plan(k1, n1, n2, "tp-aware")
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k1))
        ref = None
        for tp in (2, 4, 8):
            if tp > len(jax.devices()):
                continue
            mesh = _mesh(tp)
            psum_model = CollectiveSpec(name="psum").bytes_on_wire(
                (m, n2), tp)
            for name in comm_dispatch.strategies():
                spec = CollectiveSpec.parse(name)
                pol = ExecutionPolicy(scheme="tp-aware", backend="jnp",
                                      compute_dtype=jnp.float32,
                                      collective=spec)
                with mesh:
                    fn = lambda xx, p, pol=pol: p.forward(
                        xx, pol, mesh, activation=None)
                    coll = _collective_bytes(fn, (x, pp), mesh)
                    if name == "none":
                        err = float("nan")   # partial sums by design
                    else:
                        y = np.asarray(jax.jit(fn)(x, pp), dtype=np.float32)
                        if ref is None:
                            ref = np.asarray(
                                pp.forward(x, activation=None),
                                dtype=np.float32)
                        err = (np.abs(y - ref).max()
                               / max(np.abs(ref).max(), 1e-9))
                model = spec.bytes_on_wire((m, n2), tp)
                hvm = (abs(coll["total_per_device"] - model)
                       / max(model, 1.0))
                line = (f"{pname},{tp},{name},"
                        f"{coll['total_per_device']:.0f},{model:.0f},"
                        f"{hvm:.3f},"
                        f"{model / max(psum_model, 1):.3f},{err:.1e}")
                print(line)
                out_lines.append(line)


def _fused_wire_table(out_lines: list, m: int):
    """Fused wire epilogue vs the plain quantized collective: same wire.

    For each quant strategy × TP degree, the ``:fused`` spec must change
    *nothing* the HLO parser can see — the payload the ring moves is
    byte-for-byte what the unfused path quantizes from ``y_partial``
    (DESIGN.md §10), so measured collective bytes are identical and the
    outputs are bit-identical (both asserted, not just tabulated).  The
    fused win is the skipped 2*M*N*4 B HBM round trip inside the kernel
    (see bench_kernels' epilogue table), invisible to wire accounting by
    construction.  Small problem on purpose: the wire kernel runs in
    Pallas interpret mode on CPU."""
    title = "# bench_comm: fused wire epilogue vs plain quant (M=8)"
    print(title)
    out_lines.append(title)
    header = ("k1_n1_n2,TP,spec,epi,hlo_B,vs_plain_B,max_abs_diff")
    print(header)
    out_lines.append(header)
    k1, n1, n2 = 256, 512, 256
    pp = _plan(k1, n1, n2, "tp-aware", gs=32)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k1))
    for tp in (2, 4, 8):
        if tp > len(jax.devices()):
            continue
        mesh = _mesh(tp)
        for base in ("quant-int8:32", "quant-int4:32"):
            ys, bytes_ = {}, {}
            for epi in ("plain", "fused"):
                short = base + (":fused" if epi == "fused" else "")
                pol = ExecutionPolicy(scheme="tp-aware", backend="jnp",
                                      compute_dtype=jnp.float32,
                                      collective=CollectiveSpec.parse(short))
                with mesh:
                    fn = lambda xx, p, pol=pol: p.forward(
                        xx, pol, mesh, activation=None)
                    bytes_[epi] = _collective_bytes(
                        fn, (x, pp), mesh)["total_per_device"]
                    ys[epi] = np.asarray(jax.jit(fn)(x, pp))
            diff = float(np.abs(ys["fused"] - ys["plain"]).max())
            assert diff == 0.0, f"fused output diverged ({base}, tp={tp})"
            assert bytes_["fused"] == bytes_["plain"], (base, tp, bytes_)
            for epi in ("plain", "fused"):
                line = (f"{k1}_{n1}_{n2},{tp},{base},{epi},"
                        f"{bytes_[epi]:.0f},"
                        f"{bytes_[epi] - bytes_['plain']:.0f},{diff:.1e}")
                print(line)
                out_lines.append(line)


def _overlap_table(out_lines: list, m: int):
    """Exposed vs overlapped quantized ring (DESIGN.md §11).

    Per quant strategy × TP degree: the ``:overlap`` spec decomposes the
    two-phase ring into explicit ppermute rotations microbatch-pipelined
    against the down GEMM.  Three properties asserted per row, not just
    tabulated: the output is bit-identical to the synchronous epilogue,
    the measured HLO wire bytes are identical (only the *exposure*
    changes), and ``roofline.parse_overlap_windows`` finds ppermute
    windows spanning a GEMM in the overlapped schedule (and none in the
    synchronous one).  Wall time is reported for trend tracking but the
    hiding is only real on backends with async collectives — CPU runs
    the schedule serially, so ``wall_ms`` parity is expected here."""
    import time as _time

    title = "# bench_comm: exposed vs overlapped quant ring (M=8)"
    print(title)
    out_lines.append(title)
    header = ("k1_n1_n2,TP,spec,epi,hlo_B,spanning,wall_ms,max_abs_diff")
    print(header)
    out_lines.append(header)
    k1, n1, n2 = 256, 512, 256
    pp = _plan(k1, n1, n2, "tp-aware", gs=32)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k1))
    for tp in (2, 4, 8):
        if tp > len(jax.devices()):
            continue
        mesh = _mesh(tp)
        for base in ("quant-int8:32", "quant-int4:32"):
            ys, bytes_, spans, wall = {}, {}, {}, {}
            for epi in ("sync", "overlap"):
                short = base + (":overlap" if epi == "overlap" else "")
                pol = ExecutionPolicy(scheme="tp-aware", backend="jnp",
                                      compute_dtype=jnp.float32,
                                      collective=CollectiveSpec.parse(short))
                with mesh:
                    fn = lambda xx, p, pol=pol: p.forward(
                        xx, pol, mesh, activation=None)
                    bytes_[epi] = _collective_bytes(
                        fn, (x, pp), mesh)["total_per_device"]
                    jfn = jax.jit(fn)
                    spans[epi] = roofline.parse_overlap_windows(
                        jfn.lower(x, pp).compile().as_text())["spanning"]
                    ys[epi] = np.asarray(jfn(x, pp))
                    jfn(x, pp).block_until_ready()    # warm
                    t0 = _time.perf_counter()
                    for _ in range(5):
                        jfn(x, pp).block_until_ready()
                    wall[epi] = (_time.perf_counter() - t0) / 5 * 1e3
            diff = float(np.abs(ys["overlap"] - ys["sync"]).max())
            assert diff == 0.0, f"overlap diverged ({base}, tp={tp})"
            assert bytes_["overlap"] == bytes_["sync"], (base, tp, bytes_)
            assert spans["overlap"] >= 1, (base, tp, spans)
            assert spans["sync"] == 0, (base, tp, spans)
            for epi in ("sync", "overlap"):
                line = (f"{k1}_{n1}_{n2},{tp},{base},{epi},"
                        f"{bytes_[epi]:.0f},{spans[epi]},"
                        f"{wall[epi]:.2f},{diff:.1e}")
                print(line)
                out_lines.append(line)


#: the demo per-layer plan the third table resolves pairs against —
#: mirrors what `prepare --autotune-collectives` compiles into artifacts
PER_LAYER_PLAN = ("per-layer:*.mlp=quant-int8:128,"
                  "*.attn=cast:bfloat16,*=psum")


def _per_layer_table(out_lines: list, m: int):
    """Per-pair collective resolution under one ``CollectivePlan``.

    Two pair sites share one policy; each resolves its own spec by its
    dotted path.  ``hlo_counts`` lists the lowered collective
    instructions — the structural proof that ``layers.mlp`` runs the
    quantized all_to_all/all_gather epilogue while ``layers.attn`` runs
    a cast all-reduce and anything else falls back to psum, all within
    a single deployment plan."""
    plan = CollectivePlan.parse(PER_LAYER_PLAN)
    pol = ExecutionPolicy(scheme="tp-aware", backend="jnp",
                          compute_dtype=jnp.float32, collective=plan)
    title = f"# bench_comm: per-layer collective plan ({PER_LAYER_PLAN})"
    print(title)
    out_lines.append(title)
    header = ("problem,TP,pair_path,resolved,hlo_B,model_B,hlo_counts")
    print(header)
    out_lines.append(header)
    pname, (k1, n1, n2) = next(iter(PAPER_PROBLEMS.items()))
    pp = _plan(k1, n1, n2, "tp-aware")
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k1))
    for tp in (2, 4, 8):
        if tp > len(jax.devices()):
            continue
        mesh = _mesh(tp)
        for path in ("layers.mlp", "layers.attn", "layers.moe.experts"):
            spec = plan.resolve(path)
            with mesh:
                fn = lambda xx, p, path=path: p.forward(
                    xx, pol, mesh, activation=None, pair_path=path)
                coll = _collective_bytes(fn, (x, pp), mesh)
            model = spec.bytes_on_wire((m, n2), tp)
            counts = "+".join(f"{k}:{v}"
                              for k, v in coll["counts"].items() if v)
            line = (f"{pname},{tp},{path},{spec.shorthand()},"
                    f"{coll['total_per_device']:.0f},{model:.0f},{counts}")
            print(line)
            out_lines.append(line)


def run(out_lines: list):
    m = 8
    _scheme_table(out_lines, m)
    _strategy_table(out_lines, m)
    _fused_wire_table(out_lines, m)
    _overlap_table(out_lines, m)
    _per_layer_table(out_lines, m)


if __name__ == "__main__":
    run([])
