"""Dequant-GEMM kernel locality accounting: ordered (Algorithm 1 layout)
vs unordered (naive Eq.-3 g_idx gather).

interpret=True wall time on CPU is not TPU-meaningful, so the primary
metric is the *modeled VMEM metadata traffic* per output tile, computed
from the BlockSpecs — the quantity the paper's data-locality argument is
about: ordered layouts load ``bk/gs`` scale rows per K-tile; the unordered
layout must keep the whole (G, bn) table resident and gather per-row.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import dispatch as comm_dispatch
from repro.comm.wire import wire_params
from repro.core import quantization as qz
from repro.core.policy import ExecutionPolicy
from repro.kernels import dequant_matmul as dk, dispatch, ops


def metadata_traffic(k, n, gs, bm, bn, bk, m, *, ordered: bool) -> int:
    """Bytes of scale/zero VMEM traffic for the whole GEMM (one pass)."""
    g = k // gs
    tiles = (m // bm) * (n // bn) * (k // bk)
    if ordered:
        per_tile = (bk // gs) * bn * 4 * 2          # bk/gs rows, scales+zeros
    else:
        per_tile = g * bn * 4 * 2                   # FULL table per tile
    return tiles * per_tile


def epilogue_hbm_traffic(m, n_pad, block, bits, *, fused: bool) -> int:
    """Modeled HBM bytes the down-GEMM *epilogue* moves per forward.

    Both variants emit the same wire payload + f16 metadata (that part is
    unavoidable — it IS ring phase 1's input).  The unfused variant
    additionally round-trips the f32 partial through HBM: the dense
    kernel writes ``y_partial`` (m*n_pad*4 B) and the collective's
    quantize step reads it back.  The fused kernel (DESIGN.md §10)
    quantizes in VMEM at the last K-step, so that 2*m*n_pad*4 B vanishes.
    """
    payload = m * (n_pad if bits == 8 else n_pad // 2)
    meta_arrays = 1 if bits == 8 else 2            # scales (+zeros, int4)
    meta = m * (n_pad // block) * 2 * meta_arrays  # f16
    extra = 0 if fused else 2 * m * n_pad * 4
    return payload + meta + extra


def _fused_epilogue_table(out_lines: list):
    """Fused wire epilogue vs dense GEMM + separate blockwise quantize.

    The wall columns are interpret-mode CPU (caveat as above); the
    modeled column is the TPU-relevant one.  Bit-identity of the two
    payloads is asserted, not just tabulated — the bench doubles as a
    smoke check."""
    title = ("# bench_kernels: wire epilogue, fused vs dense+quantize "
             "(tp=4)")
    print(title)
    out_lines.append(title)
    header = ("M,K,N,gs,bits,epi,epi_hbm_B,vs_fused,wall_ms")
    print(header)
    out_lines.append(header)
    tp = 4
    for (m, k, n, gs, bits) in [(16, 4096, 256, 128, 8),
                                (16, 4096, 256, 128, 4),
                                (128, 4096, 256, 128, 8)]:
        rng = jax.random.PRNGKey(0)
        w = jax.random.normal(rng, (k, n))
        ql = qz.quantize(w, gs, act_order=True, rng=rng).ordered
        x = jax.random.normal(rng, (m, k))
        n_pad, _, bs = wire_params(n, tp, bits, 128)

        def unfused():
            y = ops.dequant_matmul(x, ql)
            if n_pad != n:
                y = jnp.pad(y, ((0, 0), (0, n_pad - n)))
            if bits == 8:
                q, s = comm_dispatch._blockwise_quantize(y, bs)
                return q.astype(jnp.int8), s, None
            q, s, z = comm_dispatch._blockwise_quantize_int4(y, bs)
            return comm_dispatch._pack4_last(q), s, z

        def fused():
            return ops.dequant_matmul_wire(x, ql, tp=tp, wire_bits=bits,
                                           wire_block=128)

        walls, outs = {}, {}
        for epi, fn in (("unfused", unfused), ("fused", fused)):
            fn()  # warm (trace + interpret setup)
            t0 = time.perf_counter()
            outs[epi] = jax.block_until_ready(fn())
            walls[epi] = (time.perf_counter() - t0) * 1e3
        for a, b in zip(outs["unfused"], outs["fused"]):
            assert (a is None) == (b is None)
            if a is not None:
                assert (np.asarray(a) == np.asarray(b)).all(), \
                    "fused payload diverged from dense+quantize"
        base = epilogue_hbm_traffic(m, n_pad, bs, bits, fused=True)
        for epi in ("unfused", "fused"):
            hbm = epilogue_hbm_traffic(m, n_pad, bs, bits,
                                       fused=(epi == "fused"))
            line = (f"{m},{k},{n},{gs},{bits},{epi},{hbm},"
                    f"{hbm / base:.1f},{walls[epi]:.1f}")
            print(line)
            out_lines.append(line)


def run(out_lines: list):
    title = "# bench_kernels: metadata VMEM traffic, ordered vs g_idx"
    print(title)
    out_lines.append(title)
    header = ("M,K,N,gs,layout,meta_bytes,ratio,interp_wall_ms")
    print(header)
    out_lines.append(header)
    for (m, k, n, gs) in [(16, 4096, 4096, 128), (16, 8192, 1792, 128),
                          (128, 4096, 4096, 128)]:
        rng = jax.random.PRNGKey(0)
        w = jax.random.normal(rng, (k, n // 16))   # small N slice for CPU
        res = qz.quantize(w, gs, act_order=True, rng=rng)
        x = jax.random.normal(rng, (m, k))
        bm, bn = min(128, m), 128
        bk = dk.pick_block_k(k, gs)

        # both layouts resolve through the dispatch registry, exactly the
        # path the deployed policy takes (backend="pallas")
        pol = ExecutionPolicy(backend="pallas").with_tiling(
            block_m=bm, block_n=bn)
        for layout, ql in (("ordered", res.ordered), ("gidx", res.naive)):
            kernel = dispatch.resolve(ql.kind, pol.backend)
            t0 = time.perf_counter()
            y = kernel(x, ql, pol)
            jax.block_until_ready(y)
            wall = (time.perf_counter() - t0) * 1e3
            meta = metadata_traffic(k, n, gs, bm, bn, bk, m,
                                    ordered=(layout == "ordered"))
            base = metadata_traffic(k, n, gs, bm, bn, bk, m, ordered=True)
            line = (f"{m},{k},{n},{gs},{layout},{meta},"
                    f"{meta / base:.1f},{wall:.1f}")
            print(line)
            out_lines.append(line)
    _fused_epilogue_table(out_lines)


if __name__ == "__main__":
    run([])
