"""Dequant-GEMM kernel locality accounting: ordered (Algorithm 1 layout)
vs unordered (naive Eq.-3 g_idx gather).

interpret=True wall time on CPU is not TPU-meaningful, so the primary
metric is the *modeled VMEM metadata traffic* per output tile, computed
from the BlockSpecs — the quantity the paper's data-locality argument is
about: ordered layouts load ``bk/gs`` scale rows per K-tile; the unordered
layout must keep the whole (G, bn) table resident and gather per-row.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import quantization as qz
from repro.core.policy import ExecutionPolicy
from repro.kernels import dequant_matmul as dk, dispatch


def metadata_traffic(k, n, gs, bm, bn, bk, m, *, ordered: bool) -> int:
    """Bytes of scale/zero VMEM traffic for the whole GEMM (one pass)."""
    g = k // gs
    tiles = (m // bm) * (n // bn) * (k // bk)
    if ordered:
        per_tile = (bk // gs) * bn * 4 * 2          # bk/gs rows, scales+zeros
    else:
        per_tile = g * bn * 4 * 2                   # FULL table per tile
    return tiles * per_tile


def run(out_lines: list):
    title = "# bench_kernels: metadata VMEM traffic, ordered vs g_idx"
    print(title)
    out_lines.append(title)
    header = ("M,K,N,gs,layout,meta_bytes,ratio,interp_wall_ms")
    print(header)
    out_lines.append(header)
    for (m, k, n, gs) in [(16, 4096, 4096, 128), (16, 8192, 1792, 128),
                          (128, 4096, 4096, 128)]:
        rng = jax.random.PRNGKey(0)
        w = jax.random.normal(rng, (k, n // 16))   # small N slice for CPU
        res = qz.quantize(w, gs, act_order=True, rng=rng)
        x = jax.random.normal(rng, (m, k))
        bm, bn = min(128, m), 128
        bk = dk.pick_block_k(k, gs)

        # both layouts resolve through the dispatch registry, exactly the
        # path the deployed policy takes (backend="pallas")
        pol = ExecutionPolicy(backend="pallas").with_tiling(
            block_m=bm, block_n=bn)
        for layout, ql in (("ordered", res.ordered), ("gidx", res.naive)):
            kernel = dispatch.resolve(ql.kind, pol.backend)
            t0 = time.perf_counter()
            y = kernel(x, ql, pol)
            jax.block_until_ready(y)
            wall = (time.perf_counter() - t0) * 1e3
            meta = metadata_traffic(k, n, gs, bm, bn, bk, m,
                                    ordered=(layout == "ordered"))
            base = metadata_traffic(k, n, gs, bm, bn, bk, m, ordered=True)
            line = (f"{m},{k},{n},{gs},{layout},{meta},"
                    f"{meta / base:.1f},{wall:.1f}")
            print(line)
            out_lines.append(line)


if __name__ == "__main__":
    run([])
