"""Beyond-paper: head-block-constrained attention V->O fold.

Measures (a) the communication the fold removes — the AllGather between
the V projection and out_proj that the paper declares out of scope — and
(b) the quantization-error cost of constraining act_order to head blocks
(block-constrained sorting is weaker than global sorting).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention_fold as af, quantization as qz
from repro.core.policy import ExecutionPolicy
from repro.launch import roofline


def run(out_lines: list):
    title = "# bench_fold: attention V->O fold (beyond paper)"
    print(title)
    out_lines.append(title)
    header = "metric,config,value"
    print(header)
    out_lines.append(header)

    # (a) communication removed: the exllama-style V->O path would
    # AllGather the (M, H*hd) attention output before out_proj; the folded
    # path needs none.  Bytes per device for Llama-70B-ish attention:
    for (h, hd, m, tp) in [(64, 128, 8, 8), (64, 128, 16, 4)]:
        gathered = m * h * hd * 4 * (tp - 1) / tp
        line = f"allgather_removed_B,(H={h} hd={hd} M={m} TP={tp}),{gathered:.0f}"
        print(line)
        out_lines.append(line)

    # (b) quantization-error cost of the block constraint
    rng = jax.random.PRNGKey(0)
    h, kv, hd, d = 16, 4, 64, 512
    r = jax.random.split(rng, 3)
    w_o = jax.random.normal(r[0], (h * hd, d)) * jnp.exp(
        jax.random.normal(r[1], (h * hd, 1)) * 0.5)   # skewed row scales
    imp = jnp.abs(jax.random.normal(r[2], (h * hd,))) + \
        jnp.abs(w_o).mean(axis=1)

    # group_size < head_dim so intra-block sorting can regroup rows
    # (at gs == head_dim every block IS one group and sorting is a no-op)
    gs = hd // 4
    # global act_order (paper Alg. 1, not TP-foldable for attention)
    q_global = qz.quantize(w_o, gs, act_order=True, importance=imp)
    # block-constrained act_order (foldable)
    order, _ = af.constrained_row_order(imp, n_heads=h, n_kv_heads=kv,
                                        head_dim=hd)
    q_block = qz.quantize(w_o, gs, act_order=True, proc_order=order)
    # no act_order at all
    q_none = qz.quantize(w_o, gs, act_order=False)

    for name, qr in (("global_actorder", q_global),
                     ("block_constrained", q_block),
                     ("no_actorder", q_none)):
        err = float(jnp.mean(jnp.abs(w_o - qz.dequantize(qr.naive))))
        line = f"quant_mae,{name},{err:.6f}"
        print(line)
        out_lines.append(line)

    # (c) the folded V->O pipeline under the deployment policy: the jnp
    # and ref dispatch backends must agree on the folded plan's output.
    rv = jax.random.split(jax.random.PRNGKey(1), 3)
    w_v = jax.random.normal(rv[0], (d, kv * hd))
    pp = af.plan_attention_vo(w_v, w_o, n_heads=h, n_kv_heads=kv,
                              head_dim=hd, group_size=hd, rng=rv[1])
    x = jax.random.normal(rv[2], (1, 4, d))
    aw = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(2), (1, h, 4, 4)), axis=-1)
    ys = {b: af.attention_vo_reference(
              x, None, aw, pp, n_heads=h, n_kv_heads=kv, head_dim=hd,
              policy=ExecutionPolicy(backend=b))
          for b in ("jnp", "ref")}
    diff = float(jnp.abs(ys["jnp"] - ys["ref"]).max())
    line = f"fold_policy_backend_agreement,max_abs_diff,{diff:.2e}"
    print(line)
    out_lines.append(line)


if __name__ == "__main__":
    run([])
