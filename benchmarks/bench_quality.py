"""Deployment-quality ablation: eval loss of a trained model, dense vs
int4-quantized under each deployment scheme.

Validates the premise the paper builds on: (a) int4 group quantization
costs little eval loss, and (b) the three deployment layouts are
quality-identical (same arithmetic) — so the scheme choice is purely a
latency/communication decision, which is the paper's whole point.
GPTQ error-feedback vs plain RTN is ablated at the pair level in
`tests/test_quantization.py` (needs per-layer calibration Hessians).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import dataclasses

from repro.configs import get_smoke_config
from repro.core.policy import ExecutionPolicy
from repro.models.common import REPLICATED
from repro.models.registry import build_model
from repro.quant.gptq import quantize_model
from repro.train import data as data_lib, optimizer as opt, trainstep


def run(out_lines: list):
    title = "# bench_quality: eval loss, dense vs int4 deployment schemes"
    print(title)
    out_lines.append(title)
    header = "config,eval_loss,delta_vs_dense"
    print(header)
    out_lines.append(header)

    cfg = get_smoke_config("granite-3-8b").with_quant(mode="none")
    model = build_model(cfg)
    state = trainstep.init_train_state(model, jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3, total_steps=150, warmup_steps=10)
    step = jax.jit(trainstep.make_train_step(model, REPLICATED, ocfg),
                   donate_argnums=0)
    dcfg = data_lib.DataConfig(seq_len=64, global_batch=8,
                               vocab_size=cfg.vocab_size)
    it = data_lib.batches(dcfg)
    for _ in range(150):
        state, metrics = step(state, next(it))

    eval_cfg = data_lib.DataConfig(seq_len=64, global_batch=8,
                                   vocab_size=cfg.vocab_size, seed=999)
    eval_batches = []
    eit = data_lib.batches(eval_cfg)
    for _ in range(4):
        eval_batches.append(next(eit))

    def eval_loss(m, params, ctx=REPLICATED):
        tot = 0.0
        for b in eval_batches:
            tot += float(trainstep.loss_fn(m, params, b, ctx))
        return tot / len(eval_batches)

    dense_loss = eval_loss(model, state["params"])
    line = f"dense,{dense_loss:.4f},0.0000"
    print(line)
    out_lines.append(line)

    for scheme in ("naive-actorder", "exllama", "tp-aware"):
        qcfg = cfg.with_quant(mode="mlp", scheme=scheme)
        # evaluate under the config's own deployment plan
        qctx = dataclasses.replace(
            REPLICATED, policy=ExecutionPolicy.from_config(qcfg))
        qparams = quantize_model(qcfg, state["params"],
                                 rng=jax.random.PRNGKey(7))
        ql = eval_loss(build_model(qcfg), qparams, qctx)
        line = f"int4-{scheme},{ql:.4f},{ql - dense_loss:+.4f}"
        print(line)
        out_lines.append(line)


if __name__ == "__main__":
    run([])
