import os
import sys

# TP benchmarks need multiple host devices (8, like the paper's 8-GPU node).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# Fallback for `python benchmarks/run.py` without PYTHONPATH=src (the
# documented invocation is `python -m benchmarks.run` from the repo root
# with PYTHONPATH=src): both the repo root (the `benchmarks` package) and
# src/ (`repro`) must be importable before any repro import below.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

"""Benchmark harness: one module per paper table/figure group.

  PYTHONPATH=src python -m benchmarks.run [--only mlp|comm|kernels|fold]

Writes a CSV transcript to results/bench.csv as well as stdout.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["mlp", "comm", "kernels", "fold", "quality"])
    ap.add_argument("--out", default="results/bench.csv")
    args = ap.parse_args()

    from benchmarks import (bench_comm, bench_fold, bench_kernels,
                            bench_mlp, bench_quality)

    suites = {
        "mlp": bench_mlp.run,        # paper Tables 1-28
        "comm": bench_comm.run,      # collective-bytes accounting
        "kernels": bench_kernels.run,  # Alg.-1 locality (ExllamaV2 kernel)
        "fold": bench_fold.run,      # beyond-paper attention fold
        "quality": bench_quality.run,  # int4 deployment quality ablation
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    lines: list = []
    for name, fn in suites.items():
        print(f"\n=== {name} ===")
        lines.append(f"=== {name} ===")
        fn(lines)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(str(l) for l in lines) + "\n")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
