import os
import sys

# TP benchmarks need multiple host devices (8, like the paper's 8-GPU node).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# Fallback for `python benchmarks/run.py` without PYTHONPATH=src (the
# documented invocation is `python -m benchmarks.run` from the repo root
# with PYTHONPATH=src): both the repo root (the `benchmarks` package) and
# src/ (`repro`) must be importable before any repro import below.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

"""Benchmark harness: one module per paper table/figure group.

  PYTHONPATH=src python -m benchmarks.run [--only mlp|comm|kernels|fold]
      [--json]

Writes a CSV transcript to results/bench.csv as well as stdout.  With
``--json``, each suite's tables also land in a committed-per-PR
``BENCH_<suite>.json`` snapshot at the repo root (git SHA + config +
structured tables — see benchmarks/snapshot.py), so the perf
trajectory is visible across PRs.

The serving load generator (``serve`` suite) is opt-in via ``--only
serve`` — it spins up a real HTTP/SSE server per TP degree; run
``benchmarks/bench_serve.py`` directly for the full arrival-rate x TP
sweep that produces the committed ``BENCH_serve.json``.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["mlp", "comm", "kernels", "fold", "quality",
                             "serve"])
    ap.add_argument("--out", default="results/bench.csv")
    ap.add_argument("--json", action="store_true",
                    help="also write a BENCH_<suite>.json snapshot per "
                         "suite at the repo root")
    args = ap.parse_args()

    from benchmarks import (bench_comm, bench_fold, bench_kernels,
                            bench_mlp, bench_quality, bench_serve,
                            snapshot)

    suites = {
        "mlp": bench_mlp.run,        # paper Tables 1-28
        "comm": bench_comm.run,      # collective-bytes accounting
        "kernels": bench_kernels.run,  # Alg.-1 locality (ExllamaV2 kernel)
        "fold": bench_fold.run,      # beyond-paper attention fold
        "quality": bench_quality.run,  # int4 deployment quality ablation
    }
    if args.only == "serve":
        suites = {"serve": bench_serve.run}   # opt-in: boots a server
    elif args.only:
        suites = {args.only: suites[args.only]}

    lines: list = []
    for name, fn in suites.items():
        print(f"\n=== {name} ===")
        lines.append(f"=== {name} ===")
        suite_lines: list = []
        fn(suite_lines)
        lines.extend(suite_lines)
        if args.json and name != "serve":
            # bench_serve writes its own richer BENCH_serve.json
            path = snapshot.write(name, config={"suite": name},
                                  metrics={"tables":
                                           snapshot.tables_from_lines(
                                               suite_lines)})
            print(f"wrote {path}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(str(l) for l in lines) + "\n")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
