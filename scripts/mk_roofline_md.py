"""Render EXPERIMENTS.md roofline tables from results/dryrun_all.jsonl."""

import json
import sys

sys.path.insert(0, "src")


def fmt_t(s):
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s * 1e6:.0f}us"
    if s < 1:
        return f"{s * 1e3:.1f}ms"
    return f"{s:.2f}s"


def fmt_b(b):
    for u in ("B", "KB", "MB", "GB", "TB", "PB"):
        if b < 1024:
            return f"{b:.1f}{u}"
        b /= 1024
    return f"{b:.1f}EB"


def main(path="results/dryrun_all.jsonl", *variants):
    """Baselines from `path`; records in `variants` files (hillclimbed
    defaults: EP MoE, vocab padding) override per (arch, shape, mesh)."""
    recs = [json.loads(l) for l in open(path)]
    skips = [r for r in recs if "skipped" in r]
    recs = [r for r in recs if "skipped" not in r]
    # dedupe: keep last record per key (later = post-fix)
    byk = {}
    for r in recs:
        byk[(r["arch"], r["shape"], r["mesh"])] = r
    for vf in variants:
        for l in open(vf):
            r = json.loads(l)
            if "skipped" not in r:
                byk[(r["arch"], r["shape"], r["mesh"])] = r
    recs = list(byk.values())

    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    order = {s: i for i, s in enumerate(shapes)}
    recs.sort(key=lambda r: (r["arch"], order[r["shape"]], r["mesh"]))

    print("### Single-pod (16x16 = 256 chips) roofline — all 40 pairs\n")
    print("| arch | shape | t_compute | t_memory | t_collective | bottleneck"
          " | HLO GFLOPs | coll bytes | HBM/dev | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        print(f"| {r['arch']} | {r['shape']} | {fmt_t(r['t_compute'])} | "
              f"{fmt_t(r['t_memory'])} | {fmt_t(r['t_collective'])} | "
              f"**{r['bottleneck']}** | {r['hlo_flops'] / 1e9:.0f} | "
              f"{fmt_b(r['collective_bytes'])} | "
              f"{fmt_b(r['per_device_hbm'])} | "
              f"{r['useful_flops_frac']:.2f} |")
    for s in skips[:1]:
        print(f"| whisper-large-v3 | long_500k | — | — | — | SKIP | — | — |"
              f" — | — |")

    print("\n### Multi-pod (2x16x16 = 512 chips) — lowering proof + memory\n")
    print("| arch | shape | compiles | HBM/dev | t_mem | bottleneck |")
    print("|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != "2x16x16":
            continue
        print(f"| {r['arch']} | {r['shape']} | yes | "
              f"{fmt_b(r['per_device_hbm'])} | {fmt_t(r['t_memory'])} | "
              f"{r['bottleneck']} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
